//! Device pool: N simulated PMCA clusters from one platform description.
//!
//! HERO exposes the accelerator as multiple clusters behind mailboxes;
//! we model that by stamping out one full SoC slice per pool cluster.
//! Each cluster spec is the base platform with the device-managed DRAM
//! partition replaced by a page-aligned slice of the original — so every
//! cluster session builds its own `hero::allocator::Arena` (disjoint
//! device addresses, physically contiguous within the slice) and its own
//! `soc::mailbox::Mailbox` (independent doorbells).  The worker thread
//! that owns a spec boots the session on itself; nothing device-side is
//! shared between clusters, which is exactly what makes the pool
//! trivially parallel.
//!
//! Slicing is planned by the [`CapacityModel`] — the one place that
//! knows both capacity dimensions of the platform (request-level
//! `sched.pool_clusters` x intra-offload `cluster.clusters` compute
//! tiles) and the byte capacity of every slice.  Two layouts:
//!
//! * **even** (`big_shape_frac = 0`, the original behavior): the
//!   partition splits into equal page-aligned slices.  Simple, but the
//!   largest device-stageable GEMM shrinks with the pool (pool 4 caps
//!   device-path n around ~800 f64 on the default 64 MiB partition).
//! * **big-shape lane** (`big_shape_frac > 0`, pool >= 2): cluster 0
//!   gets `big_shape_frac` of the partition and the rest splits evenly,
//!   so one lane regains the unpartitioned large-GEMM range while the
//!   placement router keeps small requests off it (no head-of-line
//!   blocking behind a large launch).

use crate::config::PlatformConfig;
use crate::error::{Error, Result};

/// Smallest useful DRAM slice: three padded 128x128 f64 operands plus
/// headroom.  Splitting finer than this would make every offload above
/// the Figure-3 crossover fail with OOM, so reject it at boot.
pub const MIN_SLICE_BYTES: u64 = 1 << 20;

/// One bootable cluster: its pool index and its partitioned platform.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub id: u32,
    pub cfg: PlatformConfig,
}

/// The pool's unified capacity description: how many request-level
/// clusters exist, how many intra-offload compute tiles each one drives,
/// and how many device-DRAM bytes each one can stage.  The placement
/// router sizes jobs against `slice_bytes`; `hero-blas serve` reports it;
/// the pool derives the per-cluster platforms from it — one model instead
/// of `cluster.clusters` and `sched.pool_clusters` read in isolation.
#[derive(Debug, Clone)]
pub struct CapacityModel {
    /// Device-DRAM bytes of every cluster's slice, indexed by cluster id.
    pub slice_bytes: Vec<u64>,
    /// The big-shape lane's cluster id (`Some(0)` under heterogeneous
    /// slicing; `None` for the even split).
    pub big: Option<u32>,
    /// Intra-offload compute clusters each pool cluster drives (output
    /// tiles round-robin across them within one launch).
    pub tiles_per_cluster: u32,
}

impl CapacityModel {
    /// Plan the slice layout for `clusters` pool clusters over `base`'s
    /// device-DRAM partition, honoring `[sched.placement] big_shape_frac`.
    pub fn plan(base: &PlatformConfig, clusters: u32) -> Result<CapacityModel> {
        if clusters == 0 {
            return Err(Error::Config("device pool needs at least 1 cluster".into()));
        }
        let total = base.memory.dev_dram_bytes;
        let frac = base.sched.placement.big_shape_frac;
        let (slice_bytes, big) = if base.sched.placement.big_lane(clusters) {
            let big_bytes = ((total as f64 * frac) as u64) & !4095u64;
            let small = ((total - big_bytes) / (clusters - 1) as u64) & !4095u64;
            if small < MIN_SLICE_BYTES {
                return Err(Error::Config(format!(
                    "big_shape_frac {frac} leaves {small} B per small cluster \
                     (minimum {MIN_SLICE_BYTES} B) — lower the fraction or \
                     shrink the pool"
                )));
            }
            if big_bytes < small {
                return Err(Error::Config(format!(
                    "big_shape_frac {frac} makes the big-shape slice ({big_bytes} B) \
                     smaller than a small slice ({small} B)"
                )));
            }
            let mut v = vec![small; clusters as usize];
            v[0] = big_bytes;
            (v, Some(0))
        } else {
            let slice = (total / clusters as u64) & !4095u64;
            if slice < MIN_SLICE_BYTES {
                return Err(Error::Config(format!(
                    "pool of {clusters} clusters leaves {slice} B of device DRAM each \
                     (minimum {MIN_SLICE_BYTES} B) — shrink the pool or grow \
                     memory.dev_dram_bytes"
                )));
            }
            (vec![slice; clusters as usize], None)
        };
        Ok(CapacityModel {
            slice_bytes,
            big,
            tiles_per_cluster: base.cluster.clusters,
        })
    }

    pub fn pool_clusters(&self) -> usize {
        self.slice_bytes.len()
    }

    /// Total compute tiles across the pool (the product the config
    /// validation bounds): pool clusters x intra-offload clusters.
    pub fn total_compute_tiles(&self) -> u64 {
        self.pool_clusters() as u64 * self.tiles_per_cluster as u64
    }

    /// Cluster ids of the small lanes: everything except the big-shape
    /// lane (all clusters under the even split).
    pub fn small_ids(&self) -> Vec<u32> {
        (0..self.pool_clusters() as u32)
            .filter(|c| Some(*c) != self.big)
            .collect()
    }

    /// The largest slice any cluster offers (what an oversized request
    /// needs to fit somewhere in the pool).
    pub fn max_slice(&self) -> u64 {
        self.slice_bytes.iter().copied().max().unwrap_or(0)
    }

    /// The slice of the small lanes (the routing threshold above which a
    /// job needs the big-shape lane).
    pub fn small_slice(&self) -> u64 {
        self.small_ids()
            .iter()
            .map(|&c| self.slice_bytes[c as usize])
            .min()
            .unwrap_or(0)
    }
}

/// The partitioned pool (specs only — sessions boot on worker threads).
#[derive(Debug, Clone)]
pub struct DevicePool {
    specs: Vec<ClusterSpec>,
    capacity: CapacityModel,
}

impl DevicePool {
    /// Split `base`'s device-DRAM partition into `clusters` page-aligned
    /// slices (even, or heterogeneous under a big-shape lane) and derive
    /// one per-cluster platform from each.
    pub fn partition(base: &PlatformConfig, clusters: u32) -> Result<DevicePool> {
        let capacity = CapacityModel::plan(base, clusters)?;
        let mut specs = Vec::with_capacity(clusters as usize);
        let mut next_base = base.memory.dev_dram_base;
        for (id, &bytes) in capacity.slice_bytes.iter().enumerate() {
            let mut cfg = base.clone();
            cfg.name = format!("{}/cluster{id}", base.name);
            cfg.memory.dev_dram_base = next_base;
            cfg.memory.dev_dram_bytes = bytes;
            cfg.validate()?;
            specs.push(ClusterSpec { id: id as u32, cfg });
            next_base += bytes;
        }
        Ok(DevicePool { specs, capacity })
    }

    pub fn specs(&self) -> &[ClusterSpec] {
        &self.specs
    }

    pub fn into_specs(self) -> Vec<ClusterSpec> {
        self.specs
    }

    pub fn capacity(&self) -> &CapacityModel {
        &self.capacity
    }

    pub fn size(&self) -> usize {
        self.specs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hero::device::Device;

    #[test]
    fn slices_are_disjoint_and_inside_the_original() {
        let base = PlatformConfig::default();
        let pool = DevicePool::partition(&base, 4).unwrap();
        assert_eq!(pool.size(), 4);
        let orig_end = base.memory.dev_dram_base + base.memory.dev_dram_bytes;
        let mut prev_end = base.memory.dev_dram_base;
        for spec in pool.specs() {
            let m = &spec.cfg.memory;
            assert!(m.dev_dram_base >= prev_end, "slices overlap");
            assert_eq!(m.dev_dram_base % 4096, 0);
            assert!(m.dev_dram_base + m.dev_dram_bytes <= orig_end);
            prev_end = m.dev_dram_base + m.dev_dram_bytes;
        }
        // even split of 64 MiB across 4
        assert_eq!(pool.specs()[0].cfg.memory.dev_dram_bytes, 16 * 1024 * 1024);
        assert_eq!(pool.capacity().big, None);
        assert_eq!(pool.capacity().small_ids(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn single_cluster_pool_is_the_base_partition() {
        let base = PlatformConfig::default();
        let pool = DevicePool::partition(&base, 1).unwrap();
        let m = &pool.specs()[0].cfg.memory;
        assert_eq!(m.dev_dram_base, base.memory.dev_dram_base);
        assert_eq!(m.dev_dram_bytes, base.memory.dev_dram_bytes);
    }

    #[test]
    fn rejects_zero_and_oversplit() {
        let base = PlatformConfig::default();
        assert!(DevicePool::partition(&base, 0).is_err());
        // 64 MiB / 128 = 512 KiB < MIN_SLICE_BYTES
        let e = DevicePool::partition(&base, 128).unwrap_err().to_string();
        assert!(e.contains("device DRAM"), "{e}");
    }

    #[test]
    fn big_shape_lane_gets_the_large_slice() {
        let mut base = PlatformConfig::default();
        base.sched.placement.big_shape_frac = 0.95;
        let pool = DevicePool::partition(&base, 4).unwrap();
        let cap = pool.capacity();
        assert_eq!(cap.big, Some(0));
        assert_eq!(cap.small_ids(), vec![1, 2, 3]);
        let big = cap.slice_bytes[0];
        let small = cap.slice_bytes[1];
        assert!(big > small * 8, "big lane {big} vs small {small}");
        assert!(small >= MIN_SLICE_BYTES);
        assert_eq!(cap.max_slice(), big);
        assert_eq!(cap.small_slice(), small);
        // slices stay disjoint and page-aligned
        let mut prev_end = base.memory.dev_dram_base;
        for spec in pool.specs() {
            let m = &spec.cfg.memory;
            assert!(m.dev_dram_base >= prev_end);
            assert_eq!(m.dev_dram_base % 4096, 0);
            prev_end = m.dev_dram_base + m.dev_dram_bytes;
        }
        assert!(prev_end <= base.memory.dev_dram_base + base.memory.dev_dram_bytes);

        // ISSUE 3 acceptance: the pool-4 big-shape lane must hold a
        // staged n=1600 f64 GEMM (3 padded operands) that the even split
        // cannot — the unpartitioned range, regained for one lane.
        let n1600 = 3 * 1600u64 * 1600 * 8;
        assert!(big >= n1600, "big lane {big} B cannot stage n=1600 ({n1600} B)");
        let even = DevicePool::partition(&PlatformConfig::default(), 4).unwrap();
        assert!(even.capacity().max_slice() < n1600);
    }

    #[test]
    fn big_shape_frac_rejected_when_smalls_starve() {
        let mut base = PlatformConfig::default();
        base.sched.placement.big_shape_frac = 0.97;
        // 3% of 64 MiB across 3 small clusters < 1 MiB each
        let e = DevicePool::partition(&base, 4).unwrap_err().to_string();
        assert!(e.contains("big_shape_frac"), "{e}");
        // pool of 1 ignores the fraction entirely (no lane to split off)
        base.sched.placement.big_shape_frac = 0.5;
        let pool = DevicePool::partition(&base, 1).unwrap();
        assert_eq!(pool.capacity().big, None);
    }

    #[test]
    fn capacity_model_unifies_pool_and_tiles() {
        let mut base = PlatformConfig::default();
        base.cluster.clusters = 2;
        let pool = DevicePool::partition(&base, 4).unwrap();
        assert_eq!(pool.capacity().tiles_per_cluster, 2);
        assert_eq!(pool.capacity().total_compute_tiles(), 8);
        // every per-cluster platform keeps the intra-offload width
        for spec in pool.specs() {
            assert_eq!(spec.cfg.cluster.clusters, 2);
        }
    }

    #[test]
    fn booted_clusters_have_independent_mailboxes_and_arenas() {
        let base = PlatformConfig::default();
        let pool = DevicePool::partition(&base, 2).unwrap();
        let mut devs: Vec<Device> =
            pool.specs().iter().map(|s| Device::new(&s.cfg)).collect();

        // independent DRAM arenas at disjoint device addresses
        let a0 = devs[0].dram.alloc(4096).unwrap();
        let a1 = devs[1].dram.alloc(4096).unwrap();
        assert_ne!(a0.addr, a1.addr);
        let s0 = &pool.specs()[0].cfg.memory;
        assert!(a0.addr >= s0.dev_dram_base
            && a0.addr < s0.dev_dram_base + s0.dev_dram_bytes);

        // independent mailboxes: ringing cluster 0 leaves cluster 1 idle
        devs[0].mailbox.ring_device(0xBEEF);
        assert_eq!(devs[0].mailbox.pending_for_device(), 1);
        assert_eq!(devs[1].mailbox.pending_for_device(), 0);
    }
}
