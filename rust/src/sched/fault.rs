//! Deterministic fault injection for the scheduler pool.
//!
//! The paper's platform is an FPGA-emulated heterogeneous SoC where
//! offloads can genuinely stall or fail — mailboxes hang, DMA faults,
//! clusters wedge — but the simulated device always completes.  This
//! module injects those failure modes *deterministically* so the
//! recovery machinery (retry with placement exclusion, quarantine,
//! host fallback) is reproducible under test: every decision is a pure
//! hash of `(seed, cluster, launch-seq, seam)` compared against the
//! configured per-seam rate, so the same config produces the same fault
//! schedule on every run, independent of thread interleaving.
//!
//! Three seams mirror the real failure modes:
//!
//! - **staging / DMA** ([`FaultPlan::staging_fault`]): the map-in
//!   faults.  The worker abandons the staged batch exactly like
//!   cancel-after-stage (pins and `map(alloc:)` outputs released).
//! - **mailbox timeout** ([`FaultPlan::mailbox_timeout`]): the cluster
//!   stops posting its completion word.  The worker's deadline
//!   (`deadline_factor` x the cost model's predicted cycles) trips.
//! - **compute poison** ([`FaultPlan::compute_poison`]): the batch
//!   completes but its results are marked bad and discarded.
//!
//! Injection is scoped to the *staged* device paths (gemm / gemv /
//! chain) — the seams where a real PMCA offload holds device state that
//! recovery must release.  Synchronous level-1 launches are not
//! injected.
//!
//! Every job carries a [`FaultState`]: how many device attempts have
//! faulted, which clusters faulted it (a placement exclusion bitmask),
//! and the wall time those failed attempts consumed (surfaced as the
//! span `retry_us` sub-stage, like `linger_us` not part of the
//! telescoping five-stage sum).

use crate::config::FaultConfig;

/// Per-job recovery state, threaded through requeues.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultState {
    /// Device attempts that ended in a fault (0 on the happy path).
    pub attempts: u32,
    /// Bitmask of cluster ids that faulted this job — the placement
    /// router never routes a retry back at a cluster that failed it.
    pub excluded: u64,
    /// Wall microseconds consumed by failed attempts and backoff; the
    /// reply's span breakdown reports it as the `retry` sub-stage.
    pub retry_us: u64,
}

impl FaultState {
    /// Record a fault on `cluster`, excluding it from future placement.
    pub fn note(&mut self, cluster: u32, lost_us: u64) {
        self.attempts += 1;
        self.excluded |= 1u64 << (cluster as u64 & 63);
        self.retry_us += lost_us;
    }
}

/// Which seam a fault fired at (or the detector that caught it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Map-in returned a fault while staging.
    StagingDma,
    /// The cluster never posted its completion word; the worker's
    /// deadline tripped.
    MailboxTimeout,
    /// The batch completed with its fault flag set; results discarded.
    ComputePoison,
    /// No injection: the real deadline detector fired.
    Deadline,
}

impl FaultKind {
    pub fn label(self) -> &'static str {
        match self {
            FaultKind::StagingDma => "staging-dma",
            FaultKind::MailboxTimeout => "mailbox-timeout",
            FaultKind::ComputePoison => "compute-poison",
            FaultKind::Deadline => "deadline",
        }
    }

    /// Stable numeric code for the flight recorder's compact event
    /// payload (`b` of a `fault-injected` instant).  Zero is reserved
    /// for "no fault" so a trace consumer can treat the payload as
    /// optional.
    pub fn trace_code(self) -> u64 {
        match self {
            FaultKind::StagingDma => 1,
            FaultKind::MailboxTimeout => 2,
            FaultKind::ComputePoison => 3,
            FaultKind::Deadline => 4,
        }
    }
}

/// The seeded fault schedule shared by every worker.
///
/// Cheap to clone (a copy of the config); decisions are pure functions
/// so clones agree exactly.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    cfg: FaultConfig,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_mix(mut h: u64, word: u64) -> u64 {
    for b in word.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

impl FaultPlan {
    pub fn new(cfg: FaultConfig) -> FaultPlan {
        FaultPlan { cfg }
    }

    /// A disabled plan: never injects, knobs at their defaults.
    pub fn disabled() -> FaultPlan {
        FaultPlan::new(FaultConfig::default())
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    pub fn max_attempts(&self) -> u32 {
        self.cfg.max_attempts.max(1)
    }

    pub fn backoff_ms(&self, attempts: u32) -> u64 {
        // bounded exponential: base << (attempts - 1), capped at 1 s
        let shift = attempts.saturating_sub(1).min(10);
        (self.cfg.backoff_base_ms << shift).min(1_000)
    }

    pub fn deadline_factor(&self) -> f64 {
        self.cfg.deadline_factor.max(1.0)
    }

    /// Does the plan target `cluster`?  `target_cluster < 0` means all.
    fn targets(&self, cluster: u32) -> bool {
        self.cfg.target_cluster < 0 || self.cfg.target_cluster == cluster as i64
    }

    /// Deterministic uniform draw in [0, 1) for one (cluster, seq, seam)
    /// triple under this plan's seed.
    fn roll(&self, cluster: u32, seq: u64, seam: u64) -> f64 {
        let mut h = fnv_mix(FNV_OFFSET, self.cfg.seed);
        h = fnv_mix(h, cluster as u64);
        h = fnv_mix(h, seq);
        h = fnv_mix(h, seam);
        // top 53 bits -> [0, 1)
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    fn fires(&self, rate: f64, cluster: u32, seq: u64, seam: u64) -> bool {
        self.cfg.enabled
            && rate > 0.0
            && self.targets(cluster)
            && self.roll(cluster, seq, seam) < rate
    }

    /// Should launch `seq` on `cluster` fault while staging (DMA error)?
    pub fn staging_fault(&self, cluster: u32, seq: u64) -> bool {
        self.fires(self.cfg.staging_rate, cluster, seq, 1)
    }

    /// Should launch `seq` on `cluster` hang its completion word?
    pub fn mailbox_timeout(&self, cluster: u32, seq: u64) -> bool {
        self.fires(self.cfg.mailbox_rate, cluster, seq, 2)
    }

    /// Should launch `seq` on `cluster` complete poisoned?
    pub fn compute_poison(&self, cluster: u32, seq: u64) -> bool {
        self.fires(self.cfg.poison_rate, cluster, seq, 3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(enabled: bool) -> FaultConfig {
        FaultConfig {
            enabled,
            seed: 42,
            staging_rate: 0.5,
            mailbox_rate: 0.5,
            poison_rate: 0.5,
            target_cluster: -1,
            deadline_factor: 4.0,
            max_attempts: 3,
            backoff_base_ms: 1,
            quarantine_threshold: 3,
            probe_interval: 16,
        }
    }

    #[test]
    fn disabled_plan_never_fires() {
        let p = FaultPlan::new(FaultConfig {
            staging_rate: 1.0,
            mailbox_rate: 1.0,
            poison_rate: 1.0,
            ..FaultConfig::default()
        });
        assert!(!p.enabled());
        for seq in 0..64 {
            assert!(!p.staging_fault(0, seq));
            assert!(!p.mailbox_timeout(0, seq));
            assert!(!p.compute_poison(0, seq));
        }
    }

    #[test]
    fn rate_one_always_fires_rate_zero_never() {
        let mut c = cfg(true);
        c.staging_rate = 1.0;
        c.mailbox_rate = 0.0;
        let p = FaultPlan::new(c);
        for seq in 0..64 {
            assert!(p.staging_fault(1, seq));
            assert!(!p.mailbox_timeout(1, seq));
        }
    }

    #[test]
    fn decisions_are_deterministic_and_seed_sensitive() {
        let p1 = FaultPlan::new(cfg(true));
        let p2 = FaultPlan::new(cfg(true));
        let draws1: Vec<bool> =
            (0..256).map(|s| p1.staging_fault(0, s)).collect();
        let draws2: Vec<bool> =
            (0..256).map(|s| p2.staging_fault(0, s)).collect();
        assert_eq!(draws1, draws2, "same seed => same schedule");

        let mut other = cfg(true);
        other.seed = 43;
        let p3 = FaultPlan::new(other);
        let draws3: Vec<bool> =
            (0..256).map(|s| p3.staging_fault(0, s)).collect();
        assert_ne!(draws1, draws3, "different seed => different schedule");

        // roughly the configured rate (0.5 +- a loose band over 256)
        let hits = draws1.iter().filter(|&&b| b).count();
        assert!((64..=192).contains(&hits), "rate ~0.5, got {hits}/256");
    }

    #[test]
    fn target_cluster_scopes_injection() {
        let mut c = cfg(true);
        c.staging_rate = 1.0;
        c.target_cluster = 2;
        let p = FaultPlan::new(c);
        assert!(p.staging_fault(2, 0));
        assert!(!p.staging_fault(0, 0));
        assert!(!p.staging_fault(1, 7));
    }

    #[test]
    fn seams_draw_independently() {
        let mut c = cfg(true);
        c.staging_rate = 0.5;
        c.mailbox_rate = 0.5;
        let p = FaultPlan::new(c);
        let differs = (0..256)
            .any(|s| p.staging_fault(0, s) != p.mailbox_timeout(0, s));
        assert!(differs, "seams must not alias the same draw");
    }

    #[test]
    fn fault_state_notes_exclusion_and_attempts() {
        let mut fs = FaultState::default();
        fs.note(2, 150);
        fs.note(0, 50);
        assert_eq!(fs.attempts, 2);
        assert_eq!(fs.excluded, (1 << 2) | 1);
        assert_eq!(fs.retry_us, 200);
    }

    #[test]
    fn backoff_is_bounded_exponential() {
        let p = FaultPlan::new(cfg(true));
        assert_eq!(p.backoff_ms(1), 1);
        assert_eq!(p.backoff_ms(2), 2);
        assert_eq!(p.backoff_ms(3), 4);
        assert!(p.backoff_ms(40) <= 1_000, "cap survives huge attempt counts");
    }
}
