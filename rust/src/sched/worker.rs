//! Pool workers: one thread per cluster, each owning a full offload
//! session.
//!
//! A worker boots its `HeroBlas` session *on its own thread* (engine,
//! PJRT registry and dispatch policy never cross threads), signals
//! readiness, then loops: pull a job, grow it into a batch (bounded by
//! the batcher policy AND by what the cluster's DRAM slice can stage),
//! consult the dispatch policy per job, launch, poll the cluster mailbox
//! for the completion word, join, and reply to every member.  Requests
//! complete asynchronously from the submitter's point of view — the
//! connection handler is parked on the reply channel, not on the
//! device.
//!
//! Failures are contained per batch: the device error path releases the
//! staged mappings and aborts the launch, every member gets an error
//! reply, and the worker keeps serving.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::blas::{DispatchPolicy, ExecTarget, HeroBlas};
use crate::error::Result;
use crate::metrics::SchedCounters;
use crate::soc::trace::RegionClass;
use crate::util::rng::Rng;

use super::batcher::Batcher;
use super::pool::ClusterSpec;
use super::queue::WorkQueue;
use super::{GemmOutcome, GemmRequest, Job, JobPayload};

/// Spawn one worker thread for `spec`.  It reports session boot success
/// or failure once through `ready`, then serves until the queue closes.
pub(crate) fn spawn(
    spec: ClusterSpec,
    artifacts: PathBuf,
    queue: Arc<WorkQueue>,
    counters: Arc<SchedCounters>,
    batcher: Batcher,
    ready: mpsc::Sender<Result<()>>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("sched-worker-{}", spec.id))
        .spawn(move || run(spec, artifacts, queue, counters, batcher, ready))
        .expect("spawn scheduler worker")
}

fn run(
    spec: ClusterSpec,
    artifacts: PathBuf,
    queue: Arc<WorkQueue>,
    counters: Arc<SchedCounters>,
    batcher: Batcher,
    ready: mpsc::Sender<Result<()>>,
) {
    let mut blas = match boot_session(&spec, &artifacts) {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let _ = ready.send(Ok(()));

    while let Some(job) = queue.pop_blocking() {
        match job.payload {
            JobPayload::Fence(ref release) => {
                // Park until the test/bench releases (or drops) the fence.
                let _ = release.recv();
                // counters first: a submitter that observes the reply must
                // also observe the updated metrics
                counters.completed.fetch_add(1, Ordering::Relaxed);
                let _ = job.reply.send(Ok(GemmOutcome::fence_ack(spec.id)));
            }
            JobPayload::Gemm(req) => {
                let cap = batch_cap(&blas, req.n);
                let batch = batcher.collect(&queue, job, cap);
                serve_gemm_batch(&mut blas, spec.id, &counters, batch);
            }
        }
    }
}

fn boot_session(spec: &ClusterSpec, artifacts: &PathBuf) -> Result<HeroBlas> {
    let mut blas =
        HeroBlas::new(spec.cfg.clone(), artifacts, DispatchPolicy::default())?;
    blas.registry.warm_up()?; // no compile latency on the first request
    Ok(blas)
}

/// How many batch members this cluster's DRAM slice can stage at once,
/// with 2x headroom for alignment and the L2 descriptor staging.
fn batch_cap(blas: &HeroBlas, n: usize) -> usize {
    let per_member =
        crate::blas::device::gemm_staged_bytes::<f64>(&blas.registry, (n, n, n)).max(1);
    ((blas.engine.platform.cfg.memory.dev_dram_bytes / 2) / per_member).max(1) as usize
}

/// Execute one coalesced batch and reply to every member.
fn serve_gemm_batch(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    batch: Vec<Job>,
) {
    let t0 = Instant::now();
    let b = batch.len();
    let req = match &batch[0].payload {
        JobPayload::Gemm(r) => *r,
        // collect() only coalesces around a gemm job
        JobPayload::Fence(_) => unreachable!("fence in a gemm batch"),
    };
    let queue_ms: Vec<f64> = batch
        .iter()
        .map(|j| j.enqueued_at.elapsed().as_secs_f64() * 1e3)
        .collect();

    blas.policy = DispatchPolicy::with_mode(req.mode);
    blas.reset_run();
    let result = execute_batch(blas, &batch);

    match result {
        Ok(checksums) => {
            let f = blas.engine.freq_hz();
            let t = blas.trace();
            // Uniform shapes => each member gets an even share of the
            // batch's virtual time; fork/join was paid once for all B.
            let per = |c: RegionClass| t.total(c).to_ns(f) / 1e6 / b as f64;
            let total = t.grand_total().to_ns(f) / 1e6 / b as f64;
            // counters before replies: a submitter that observes its
            // reply must also observe the updated metrics
            counters.completed.fetch_add(b as u64, Ordering::Relaxed);
            counters.batches.fetch_add(1, Ordering::Relaxed);
            if b > 1 {
                counters.batched_jobs.fetch_add(b as u64, Ordering::Relaxed);
            }
            counters.note_service_us((t0.elapsed().as_micros() as u64 / b as u64).max(1));
            for ((job, checksum), wait) in batch.iter().zip(&checksums).zip(&queue_ms) {
                let _ = job.reply.send(Ok(GemmOutcome {
                    n: req.n,
                    mode: req.mode,
                    checksum: *checksum,
                    data_copy_ms: per(RegionClass::DataCopy),
                    fork_join_ms: per(RegionClass::ForkJoin),
                    compute_ms: per(RegionClass::Compute),
                    host_compute_ms: per(RegionClass::HostCompute),
                    total_ms: total,
                    cluster,
                    batch_size: b,
                    queue_ms: *wait,
                }));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            counters.failed.fetch_add(b as u64, Ordering::Relaxed);
            counters.batches.fetch_add(1, Ordering::Relaxed);
            for job in &batch {
                let _ = job.reply.send(Err(msg.clone()));
            }
        }
    }
}

/// Synthesize every member's operands from its seed and run the batch on
/// the policy's target, returning per-member checksums.
fn execute_batch(blas: &mut HeroBlas, batch: &[Job]) -> Result<Vec<f64>> {
    let reqs: Vec<GemmRequest> = batch
        .iter()
        .map(|j| match &j.payload {
            JobPayload::Gemm(r) => *r,
            JobPayload::Fence(_) => unreachable!("fence in a gemm batch"),
        })
        .collect();
    let n = reqs[0].n;
    let mut data: Vec<(Vec<f64>, Vec<f64>, Vec<f64>)> = reqs
        .iter()
        .map(|r| {
            let mut rng = Rng::new(r.seed);
            (rng.normal_vec(n * n), rng.normal_vec(n * n), vec![0.0; n * n])
        })
        .collect();

    match blas.policy.gemm(n, n, n) {
        ExecTarget::Host => {
            for (a, b, c) in data.iter_mut() {
                blas.gemm(
                    crate::blas::Transpose::No,
                    crate::blas::Transpose::No,
                    1.0,
                    a,
                    (n, n),
                    b,
                    (n, n),
                    0.0,
                    c,
                    (n, n),
                )?;
            }
        }
        target => {
            let zero_copy = target == ExecTarget::DeviceZeroCopy;
            let run = {
                let inputs: Vec<(&[f64], &[f64], &[f64])> = data
                    .iter()
                    .map(|(a, b, c)| (a.as_slice(), b.as_slice(), c.as_slice()))
                    .collect();
                blas.gemm_batch_launch((n, n, n), 1.0, 0.0, &inputs, zero_copy)?
            };
            // Completion wait, Hero-runtime style: poll the cluster
            // mailbox for the status word before joining.  In the
            // synchronous simulator the word is already posted when
            // launch returns, so this never spins — it exists to keep
            // the worker protocol-shaped for a backend where compute
            // genuinely overlaps the host (the launch/finish split is
            // what makes that future possible).
            while !blas.offload_completion_pending() {
                std::thread::yield_now();
            }
            let mut outs: Vec<&mut [f64]> =
                data.iter_mut().map(|(_, _, c)| c.as_mut_slice()).collect();
            blas.gemm_batch_finish(run, &mut outs)?;
        }
    }
    Ok(data.iter().map(|(_, _, c)| c.iter().sum()).collect())
}

impl GemmOutcome {
    /// Ack for a fence job (no compute, no checksum).
    pub(crate) fn fence_ack(cluster: u32) -> GemmOutcome {
        GemmOutcome {
            n: 0,
            mode: crate::config::DispatchMode::HostOnly,
            checksum: 0.0,
            data_copy_ms: 0.0,
            fork_join_ms: 0.0,
            compute_ms: 0.0,
            host_compute_ms: 0.0,
            total_ms: 0.0,
            cluster,
            batch_size: 1,
            queue_ms: 0.0,
        }
    }
}
