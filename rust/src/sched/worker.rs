//! Pool workers: one thread per cluster, each owning a full offload
//! session and serving its own placement-routed run queue.
//!
//! A worker boots its `HeroBlas` session *on its own thread* (engine,
//! PJRT registry and dispatch policy never cross threads), signals
//! readiness, then loops: ask the placement router for the next job
//! (own run queue first, then a steal from the most-loaded peer — see
//! [`super::placement`]), grow it into a batch (bounded by the batcher
//! policy AND by what the cluster's DRAM slice can stage), consult the
//! dispatch policy per batch, launch, poll the cluster mailbox for the
//! completion word, join, and reply to every member.  Requests complete
//! asynchronously from the submitter's point of view — the connection
//! handler is parked on the reply channel, not on the device.
//!
//! **Cancellation**: a job whose submitter stopped waiting (serve-layer
//! reply timeout sets its [`CancelToken`]) is skipped at dequeue — never
//! synthesized, staged or launched for a dropped receiver.  A batch (or
//! chain) whose every member cancelled *while staging* is abandoned
//! before its doorbell: the staged mappings — operand-cache pins and
//! `map(alloc:)` output buffers included — are released, and the worker
//! asserts at every quiesce point that no cache pin survived
//! ([`debug_assert_pins_drained`]), so a cancelled chain can never
//! strand an unevictable resident intermediate.
//!
//! **Software pipelining** (`[sched.cache] pipeline_depth >= 2`): the
//! gemm *and gemv* device paths are split stage / execute / finish, and
//! the worker holds one executed-but-unfinished batch in flight.  When
//! the next batch arrives, its map-in is staged *before* the in-flight
//! batch is finished — i.e. during the window the in-flight batch's
//! compute occupies on a real device — so up to
//! `min(map_in(k+1), compute(k))` virtual cycles of data-copy are
//! hidden.  The hidden share is subtracted from the reported
//! per-request times and accumulated in the `overlap_hidden_us`
//! counter; checksums are unaffected (the data path is identical, only
//! the attribution changes).  The cluster's DRAM slice must hold two
//! staged batches at once, so the per-batch capacity cap is divided by
//! the pipeline depth.  Gemm and gemv batches interleave freely in the
//! pipeline — the in-flight handle carries its own kind.
//!
//! **Affinity bookkeeping**: after staging a gemm batch, the worker
//! tags the cache entries backing tracked B operands (shared `b_seed`)
//! and records residency in the router's affinity directory; after
//! every batch it drains the cache's eviction feed so the directory
//! never steers requests at a cluster that dropped the bytes.
//!
//! Failures are contained per batch: the device error path releases the
//! staged mappings and aborts the launch, every member gets an error
//! reply, and the worker keeps serving.  A staging failure while a batch
//! is in flight first drains the pipeline (freeing its DRAM) and retries
//! once serially before giving up.
//!
//! **Fault tolerance** (`[sched.fault]`, see [`super::fault`]): with a
//! fault plan enabled the worker injects deterministic failures at three
//! seams — staging/DMA, mailbox timeout, compute poison — and runs every
//! launched batch under a deadline derived from the cost model's
//! predicted cycles (`deadline_factor` x the estimate).  A faulted batch
//! is abandoned exactly like cancel-after-stage (pins and `map(alloc:)`
//! outputs released), the cluster's operand cache and affinity-directory
//! entries are invalidated, and the fault is reported to the router's
//! quarantine accounting.  Each member is then resubmitted with bounded
//! exponential backoff and a placement exclusion bit for the failed
//! cluster — or, when its attempts are exhausted or no healthy cluster
//! remains, served inline by the host BLAS path, checksum-identical by
//! construction, with `degraded: true` and its attempt count in the
//! reply.  With the plan disabled (the default) none of this arms and
//! the serve path is byte-for-byte the pre-fault behavior.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::blas::{
    ChainLink, ChainRun, DagNode, DagRun, DispatchPolicy, ExecTarget,
    GemmBatchRun, GemvBatchRun, HeroBlas,
};
use crate::cost::CostModel;
use crate::dag::{DagOp, DagShape};
use crate::error::Result;
use crate::hero::offload::OffloadKind;
use crate::kernel::{Epilogue, KernelRegistry};
use crate::metrics::{Metrics, SchedCounters};
use crate::omp::opcache::CacheEvent;
use crate::soc::clock::Cycles;
use crate::soc::trace::RegionClass;
use crate::util::rng::Rng;

use super::affinity::{chain_b_key, dag_fuse_key, operand_key};
use super::batcher::Batcher;
use super::placement::{ClusterView, PlacementRouter};
use super::pool::ClusterSpec;
use super::queue::WorkQueue;
use super::span::{BatchMarks, SpanBreakdown};
use super::trace::{EventKind, TraceRecorder};
use super::{
    ChainRequest, DagRequest, FaultKind, FaultPlan, GemmOutcome,
    GemmRequest, GemvRequest, Job, JobPayload, Level1Op, Level1Request,
    SpanStamps,
};

/// Spawn one worker thread for `spec`.  It reports session boot success
/// or failure once through `ready`, then serves until the queue closes.
/// `cost` is the pool-shared cost model — the worker's dispatch runs on
/// it (so every cluster calibrates ONE estimator, not per-session ones).
#[allow(clippy::too_many_arguments)]
pub(crate) fn spawn(
    spec: ClusterSpec,
    artifacts: PathBuf,
    queue: Arc<WorkQueue>,
    router: Arc<PlacementRouter>,
    counters: Arc<SchedCounters>,
    batcher: Batcher,
    cost: CostModel,
    fault: FaultPlan,
    trace: Arc<TraceRecorder>,
    kernel: Arc<KernelRegistry>,
    ready: mpsc::Sender<Result<()>>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("sched-worker-{}", spec.id))
        .spawn(move || {
            run(
                spec, artifacts, queue, router, counters, batcher, cost,
                fault, trace, kernel, ready,
            )
        })
        .expect("spawn scheduler worker")
}

/// Per-batch accounting (virtual-time totals in cycles, accumulated
/// across the stage / execute / finish phases from trace-region deltas,
/// so two interleaved pipeline batches never steal each other's time —
/// plus the staging conditions the calibration must predict with).
#[derive(Debug, Default, Clone, Copy)]
struct BatchAcct {
    data_copy: u64,
    fork_join: u64,
    compute: u64,
    host_compute: u64,
    /// Map-in cycles hidden under the previous batch's compute window
    /// (subtracted from `data_copy` and the total when reporting).
    hidden: u64,
    /// Did the batch stage with B cache-warm (resident or prefetched)?
    /// The calibration feedback predicts with the same warmth, so an
    /// elided map-in never reads as "device faster than predicted".
    warm_b: bool,
}

impl BatchAcct {
    fn add(&mut self, other: BatchAcct) {
        self.data_copy += other.data_copy;
        self.fork_join += other.fork_join;
        self.compute += other.compute;
        self.host_compute += other.host_compute;
    }
}

/// Trace-region totals at a point in time.
#[derive(Debug, Clone, Copy)]
struct RegionSnap {
    dc: Cycles,
    fj: Cycles,
    cp: Cycles,
    hc: Cycles,
}

fn snap(blas: &HeroBlas) -> RegionSnap {
    let t = blas.trace();
    RegionSnap {
        dc: t.total(RegionClass::DataCopy),
        fj: t.total(RegionClass::ForkJoin),
        cp: t.total(RegionClass::Compute),
        hc: t.total(RegionClass::HostCompute),
    }
}

fn delta(before: RegionSnap, after: RegionSnap) -> BatchAcct {
    BatchAcct {
        data_copy: after.dc.saturating_sub(before.dc).0,
        fork_join: after.fj.saturating_sub(before.fj).0,
        compute: after.cp.saturating_sub(before.cp).0,
        host_compute: after.hc.saturating_sub(before.hc).0,
        hidden: 0,
        warm_b: false,
    }
}

/// A published DAG output held for cross-request fusion: the producer's
/// last-sink result, keyed by the request-chosen `publish_key`, alive
/// until `[sched.dag] fuse_window_ms` elapses or a consumer splices it.
/// One slot per worker — each publish overwrites the previous one, the
/// pattern a pipelined producer/consumer stream actually produces.
struct FuseSlot {
    key: u64,
    rows: usize,
    width: usize,
    data: Vec<f64>,
    expires_at: Instant,
}

/// Per-worker cross-request fusion state: the single published-output
/// slot plus the configured window that bounds its lifetime.
struct FuseState {
    slot: Option<FuseSlot>,
    window_ms: u64,
}

/// The executed-but-unfinished payload of a pipelined batch.
enum InflightRun {
    Gemm {
        req: GemmRequest,
        data: Vec<(Vec<f64>, Vec<f64>, Vec<f64>)>,
        run: GemmBatchRun<f64>,
    },
    // No member (A, x) data here: the device mappings are backed by the
    // padded byte images owned by the batch state, so the synthesized
    // operands are dropped as soon as staging returns instead of being
    // held across the in-flight window.
    Gemv {
        req: GemvRequest,
        ys: Vec<Vec<f64>>,
        run: GemvBatchRun<f64>,
    },
    /// A chained job: every link executed, intermediates resident on the
    /// cluster, only the final output pending its copy back.
    Chain {
        req: ChainRequest,
        out: Vec<f64>,
        run: ChainRun<f64>,
    },
    /// A DAG job: every node executed in topological order, interior
    /// edges resident on the cluster, only the sink outputs pending
    /// their copy back (and possibly a publish for cross-request
    /// fusion).
    Dag {
        req: DagRequest,
        outs: Vec<Vec<f64>>,
        run: DagRun<f64>,
    },
}

/// One coalesced batch between its execute and its finish: the
/// completion word is posted in the cluster mailbox, results are still
/// on the device, replies are pending.
struct Inflight {
    jobs: Vec<Job>,
    run: InflightRun,
    acct: BatchAcct,
    queue_ms: Vec<f64>,
    /// Wall microseconds this batch actively consumed through execute.
    /// The finish phase adds its own elapsed time — the idle gap while
    /// the batch sits in flight waiting for the next arrival must NOT
    /// count, or the service-time EWMA (and with it the retry-after
    /// backpressure hint) inflates under pipelining.
    work_us: u64,
    /// Batch assembly done (stage span's linger boundary).
    collected_at: Instant,
    /// Fork-join launch issued (stage span ends, execute begins).  The
    /// finish phase supplies `done_at` when it observes completion.
    exec_at: Instant,
    /// Injected fault decided at execute time (mailbox timeout / compute
    /// poison): the finish phase discards the results and routes the
    /// batch into recovery instead of replying.
    fault: Option<FaultKind>,
    /// Completion deadline (`deadline_factor` x the cost model's
    /// predicted cycles, in virtual time).  Armed only when the fault
    /// plan is enabled; an expiry while the completion word is pending
    /// marks the batch [`FaultKind::Deadline`].
    deadline: Option<Instant>,
}

#[allow(clippy::too_many_arguments)]
fn run(
    spec: ClusterSpec,
    artifacts: PathBuf,
    queue: Arc<WorkQueue>,
    router: Arc<PlacementRouter>,
    counters: Arc<SchedCounters>,
    batcher: Batcher,
    cost: CostModel,
    fault: FaultPlan,
    trace: Arc<TraceRecorder>,
    kernel: Arc<KernelRegistry>,
    ready: mpsc::Sender<Result<()>>,
) {
    let mut blas = match boot_session(&spec, &artifacts) {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    // swap the session's private model for the pool-shared one: every
    // worker's Auto dispatch reads (and calibrates) the same estimator
    blas.policy.model = Some(cost);
    // attach the pool-shared kernel registry: device staging consults it
    // for promoted fast-path plans, serve paths feed launch counts in
    if kernel.enabled() && spec.cfg.kernel.prewarm && spec.id == 0 {
        // one worker prewarms for the whole pool — the registry is
        // shared and the AOT size tables are cluster-independent
        // (each insert fires the Promote hook into the flight recorder)
        let _ = kernel.prewarm(
            &blas.engine.platform.dma,
            &blas.engine.platform.cluster,
        );
    }
    blas.policy.kernel = Some(Arc::clone(&kernel));
    // bridge the operand cache's transitions into the flight recorder —
    // the hook carries its own recorder handle and cluster id, so the
    // omp layer never learns about the scheduler
    {
        let t = Arc::clone(&trace);
        let cl = spec.id;
        blas.engine.opcache.set_event_hook(move |ev| match ev {
            CacheEvent::Hit { bytes } => {
                t.instant(cl, EventKind::CacheHit, bytes, 0)
            }
            CacheEvent::Miss => t.instant(cl, EventKind::CacheMiss, 0, 0),
            CacheEvent::Evict { bytes } => {
                t.instant(cl, EventKind::CacheEvict, bytes, 0)
            }
        });
    }
    let _ = ready.send(Ok(()));

    let cid = spec.id as usize;
    // double-buffered staging: depth 2 is what the implementation holds
    let depth = (spec.cfg.sched.cache.pipeline_depth as usize).clamp(1, 2);
    let mut inflight: Option<Inflight> = None;
    // cross-request fusion: the last published DAG output on this worker
    let mut fuse = FuseState {
        slot: None,
        window_ms: spec.cfg.sched.dag.fuse_window_ms,
    };
    let mut metrics_prev = blas.metrics();
    // per-worker launch attempt counter: the fault plan's deterministic
    // schedule is keyed on (cluster, launch-seq, seam)
    let mut launch_seq: u64 = 0;

    loop {
        // With a batch in flight never park: an empty run queue means
        // "drain the pipeline now", not "sleep while a client waits".
        let next = if inflight.is_some() {
            router.try_next(cid, &queue, &counters)
        } else {
            match router.next(cid, &queue, &counters) {
                Some(j) => Some(j),
                None => break, // closed and drained; nothing in flight
            }
        };
        let Some(job) = next else {
            let infl = inflight.take().expect("try_next only used with inflight");
            finish_batch(
                &mut blas, spec.id, &counters, &router, &fault, &queue,
                &trace, infl, &mut fuse, &mut metrics_prev,
            );
            // pipeline drained, nothing staged: every operand-cache pin
            // must be back — a leak here strands unevictable DRAM
            check_pins_drained(&blas, &counters, spec.id);
            continue;
        };

        // Cancellation at dequeue: the submitter stopped waiting, so the
        // job is dropped before any synthesis or staging happens.
        if job.cancel.is_cancelled() {
            counters.cancelled.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        // claimed-but-not-replied gauge (the serve `top` op); batch
        // peels below add their members, every reply path subtracts
        inflight_add(&counters, spec.id, 1);

        let source = ClusterView {
            router: &router,
            queue: &queue,
            counters: &counters,
            cluster: cid,
        };
        match job.payload {
            JobPayload::Fence(ref release) => {
                // A fence drains the pipeline first: it is a barrier.
                if let Some(infl) = inflight.take() {
                    finish_batch(
                        &mut blas, spec.id, &counters, &router, &fault,
                        &queue, &trace, infl, &mut fuse, &mut metrics_prev,
                    );
                }
                // Park until the test/bench releases (or drops) the fence.
                let _ = release.recv();
                // counters first: a submitter that observes the reply must
                // also observe the updated metrics
                counters.completed.fetch_add(1, Ordering::Relaxed);
                if let Some(pc) = counters.cluster(spec.id) {
                    pc.completed.fetch_add(1, Ordering::Relaxed);
                }
                inflight_sub(&counters, spec.id, 1);
                let _ = job.reply.send(Ok(GemmOutcome::fence_ack(spec.id)));
            }
            JobPayload::Gemv(req) => {
                let cap = (gemv_batch_cap(&blas, req.m, req.n) / depth).max(1);
                let mut batch = batcher.collect(&source, job, cap);
                inflight_add(&counters, spec.id, batch.len() as u64 - 1);
                drop_cancelled(&mut batch, &counters, spec.id);
                if batch.is_empty() {
                    continue;
                }
                serve_gemv(
                    &mut blas,
                    spec.id,
                    &counters,
                    &router,
                    &fault,
                    &queue,
                    &trace,
                    &mut launch_seq,
                    batch,
                    req,
                    depth,
                    &mut fuse,
                    &mut inflight,
                    &mut metrics_prev,
                );
            }
            JobPayload::Level1(req) => {
                // level-1 chunks are DMA-bound and stage transiently:
                // run the coalesced batch synchronously
                if let Some(infl) = inflight.take() {
                    finish_batch(
                        &mut blas, spec.id, &counters, &router, &fault,
                        &queue, &trace, infl, &mut fuse, &mut metrics_prev,
                    );
                }
                let mut batch = batcher.collect(&source, job, usize::MAX);
                inflight_add(&counters, spec.id, batch.len() as u64 - 1);
                drop_cancelled(&mut batch, &counters, spec.id);
                if batch.is_empty() {
                    continue;
                }
                serve_level1(
                    &mut blas, spec.id, &counters, &router, &trace, batch,
                    req, &mut metrics_prev,
                );
            }
            JobPayload::Chain(ref req) => {
                let req = req.clone();
                serve_chain(
                    &mut blas,
                    spec.id,
                    &counters,
                    &router,
                    &fault,
                    &queue,
                    &trace,
                    &mut launch_seq,
                    job,
                    req,
                    depth,
                    &mut fuse,
                    &mut inflight,
                    &mut metrics_prev,
                );
            }
            JobPayload::Dag(ref req) => {
                let req = req.clone();
                serve_dag(
                    &mut blas,
                    spec.id,
                    &counters,
                    &router,
                    &fault,
                    &queue,
                    &trace,
                    &mut launch_seq,
                    job,
                    req,
                    depth,
                    &mut fuse,
                    &mut inflight,
                    &mut metrics_prev,
                );
            }
            JobPayload::Gemm(req) => {
                // Cache-aware dispatch: B predicted resident on THIS
                // cluster (per the affinity directory) drops the map-in
                // cost from the model's estimate, so a warm shared-B
                // stream offloads below the cold crossover.
                blas.policy.mode = req.mode;
                let b_key = req
                    .b_seed
                    .filter(|_| router.affinity_enabled())
                    .map(|bs| operand_key("gemm_b", req.n, bs));
                let mut warm_b = b_key.is_some_and(|k| router.is_resident(k, spec.id));
                let target = blas.policy.gemm_warm(req.n, req.n, req.n, warm_b);
                // Directory-driven prefetch: a device-bound shared-B job
                // at a cold home pre-stages B during the linger window,
                // so the miss cost lands outside the batch's regions
                // (copy mode only — zero-copy staging bypasses the cache).
                // A successful prefetch makes the batch warm.
                if target == ExecTarget::Device && !warm_b && blas.engine.cache_enabled() {
                    if let (Some(key), Some(bs)) = (b_key, req.b_seed) {
                        warm_b = prefetch_b(
                            &mut blas, &router, &counters, &trace, spec.id,
                            req.n, bs, key,
                        );
                    }
                }
                let cap = (gemm_batch_cap(&blas, req.n) / depth).max(1);
                // the linger decision must agree with the (cache-aware)
                // decision that launches, not a cold re-derivation
                let mut batch = batcher.collect_decided(
                    &source,
                    job,
                    cap,
                    Some(target != ExecTarget::Host),
                );
                inflight_add(&counters, spec.id, batch.len() as u64 - 1);
                drop_cancelled(&mut batch, &counters, spec.id);
                if batch.is_empty() {
                    continue;
                }
                serve_gemm(
                    &mut blas,
                    spec.id,
                    &counters,
                    &router,
                    &fault,
                    &queue,
                    &trace,
                    &mut launch_seq,
                    batch,
                    req,
                    target,
                    warm_b,
                    depth,
                    &mut fuse,
                    &mut inflight,
                    &mut metrics_prev,
                );
            }
        }
    }

    // shutdown: drain whatever is still in flight before exiting
    if let Some(infl) = inflight.take() {
        finish_batch(
            &mut blas, spec.id, &counters, &router, &fault, &queue, &trace,
            infl, &mut fuse, &mut metrics_prev,
        );
    }
    check_pins_drained(&blas, &counters, spec.id);
}

/// Between batches — nothing staged, nothing in flight — every
/// operand-cache pin must have been released.  A cancelled or failed
/// chain that stranded a pinned intermediate would hold device DRAM
/// forever (pinned entries are never evicted).  Debug builds still
/// panic; release builds count the leak into the `pin_leaks` counter
/// (surfaced through serve `metrics`) instead of silently compiling the
/// check out — a production leak shows up on the dashboard, not as an
/// unexplainable capacity loss.
fn check_pins_drained(blas: &HeroBlas, counters: &SchedCounters, cluster: u32) {
    let pins = blas.engine.opcache.total_pins();
    if pins != 0 {
        counters.pin_leaks.fetch_add(1, Ordering::Relaxed);
        if let Some(pc) = counters.cluster(cluster) {
            pc.pin_leaks.fetch_add(1, Ordering::Relaxed);
        }
        debug_assert_eq!(
            pins, 0,
            "operand-cache pins stranded after the pipeline drained"
        );
    }
}

fn boot_session(spec: &ClusterSpec, artifacts: &PathBuf) -> Result<HeroBlas> {
    let mut blas =
        HeroBlas::new(spec.cfg.clone(), artifacts, DispatchPolicy::default())?;
    blas.registry.warm_up()?; // no compile latency on the first request
    Ok(blas)
}

/// Remove members whose submitter cancelled while they were queued.
fn drop_cancelled(batch: &mut Vec<Job>, counters: &SchedCounters, cluster: u32) {
    batch.retain(|j| {
        if j.cancel.is_cancelled() {
            counters.cancelled.fetch_add(1, Ordering::Relaxed);
            inflight_sub(counters, cluster, 1);
            false
        } else {
            true
        }
    });
}

/// Raise the cluster's claimed-but-not-replied gauge by `k`.
fn inflight_add(counters: &SchedCounters, cluster: u32, k: u64) {
    if k == 0 {
        return;
    }
    if let Some(pc) = counters.cluster(cluster) {
        pc.inflight.fetch_add(k, Ordering::Relaxed);
    }
}

/// Lower the gauge by `k`, saturating at zero (a stale snapshot must
/// never wrap the gauge to u64::MAX).
fn inflight_sub(counters: &SchedCounters, cluster: u32, k: u64) {
    if k == 0 {
        return;
    }
    if let Some(pc) = counters.cluster(cluster) {
        let _ = pc.inflight.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| Some(v.saturating_sub(k)),
        );
    }
}

/// How many batch members this cluster's DRAM slice can stage at once,
/// with 2x headroom for alignment and the L2 descriptor staging.  The
/// pipelined worker divides this further by the pipeline depth, since
/// two batches' operands are resident at once.
fn gemm_batch_cap(blas: &HeroBlas, n: usize) -> usize {
    let per_member =
        crate::blas::device::gemm_staged_bytes::<f64>(&blas.registry, (n, n, n)).max(1);
    ((blas.engine.platform.cfg.memory.dev_dram_bytes / 2) / per_member).max(1) as usize
}

/// Same bound for a coalesced gemv batch.
fn gemv_batch_cap(blas: &HeroBlas, m: usize, n: usize) -> usize {
    let per_member =
        crate::blas::device::gemv_staged_bytes::<f64>(&blas.registry, (m, n)).max(1);
    ((blas.engine.platform.cfg.memory.dev_dram_bytes / 2) / per_member).max(1) as usize
}

/// Synthesize one gemm member's operands from its seeds: A continues the
/// request RNG stream; B either continues it (classic behavior) or comes
/// from its own `b_seed` stream, so same-`b_seed` requests share a
/// bit-identical B — the pattern the operand cache collapses into
/// refcount bumps (and the placement router routes to one cluster).
fn synth_gemm(req: &GemmRequest, seed: u64, b_seed: Option<u64>)
              -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let n = req.n;
    let mut rng = Rng::new(seed);
    let a = rng.normal_vec(n * n);
    let b = match b_seed {
        None => rng.normal_vec(n * n),
        Some(s) => Rng::new(s).normal_vec(n * n),
    };
    (a, b, vec![0.0; n * n])
}

/// Wall-clock queue wait of every member, ms.
fn queue_waits(batch: &[Job]) -> Vec<f64> {
    batch
        .iter()
        .map(|j| j.enqueued_at.elapsed().as_secs_f64() * 1e3)
        .collect()
}

fn virt_us(blas: &HeroBlas, cycles: u64) -> u64 {
    (Cycles(cycles).to_ns(blas.engine.freq_hz()) / 1e3) as u64
}

/// Drain the operand cache's eviction feed into the router's affinity
/// directory (tags of tracked operands that were reclaimed).
fn sync_directory(blas: &mut HeroBlas, router: &PlacementRouter, cluster: u32) {
    for tag in blas.engine.opcache.take_evicted_tags() {
        router.note_evicted(tag, cluster);
    }
}

/// Map-in cycles hidden under the previous batch's compute window —
/// the cost model's overlap accounting (min of the two regions; the
/// model is the single place that rule lives).
fn overlap_credit(blas: &HeroBlas, map_in: u64, prev_compute: u64) -> u64 {
    match &blas.policy.model {
        Some(cm) => cm.overlap_credit(map_in, prev_compute),
        None => map_in.min(prev_compute),
    }
}

/// Execute-time injection: decide whether this launch hangs its mailbox
/// completion word or completes poisoned (independent draws; mailbox
/// wins when both fire).  Counted the moment it is decided — the finish
/// phase acts on it when the batch drains.
fn launch_fault(
    plan: &FaultPlan,
    counters: &SchedCounters,
    cluster: u32,
    seq: u64,
) -> Option<FaultKind> {
    let kind = if plan.mailbox_timeout(cluster, seq) {
        Some(FaultKind::MailboxTimeout)
    } else if plan.compute_poison(cluster, seq) {
        Some(FaultKind::ComputePoison)
    } else {
        None
    };
    if kind.is_some() {
        counters.faults_injected.fetch_add(1, Ordering::Relaxed);
    }
    kind
}

/// Completion deadline for a launched batch: `deadline_factor` x the
/// cost model's predicted cycles, converted to virtual-time
/// microseconds (floored so a tiny estimate never arms a zero-length
/// deadline).  Armed only while the fault plan is enabled — with the
/// `[sched.fault]` section absent the finish poll is byte-for-byte the
/// pre-fault behavior.
fn completion_deadline(
    blas: &HeroBlas,
    plan: &FaultPlan,
    exec_at: Instant,
    predict: impl FnOnce(&CostModel) -> f64,
) -> Option<Instant> {
    if !plan.enabled() {
        return None;
    }
    blas.policy.model.as_ref().map(|cm| {
        let cycles = (predict(cm) * plan.deadline_factor()) as u64;
        let us = virt_us(blas, cycles).max(50);
        exec_at + Duration::from_micros(us)
    })
}

/// Directory-driven prefetch: synthesize the shared B from its seed and
/// pre-stage it into this cluster's operand cache while the batcher
/// would otherwise just linger — the batch that follows hits instead of
/// missing, and the copy cost lands outside the batch's accounted
/// regions.  Best-effort: an OOM or staging error simply leaves the
/// batch to pay its own miss.  Returns whether B is now resident (the
/// batch will stage warm).
#[allow(clippy::too_many_arguments)]
fn prefetch_b(
    blas: &mut HeroBlas,
    router: &PlacementRouter,
    counters: &SchedCounters,
    trace: &TraceRecorder,
    cluster: u32,
    n: usize,
    b_seed: u64,
    key: u64,
) -> bool {
    let b = Rng::new(b_seed).normal_vec(n * n);
    let resident = if let Ok(Some(ck)) = blas.prefetch_gemm_b(n, &b) {
        blas.engine.opcache.set_tag(&ck, key);
        router.note_resident(key, cluster);
        counters.prefetched.fetch_add(1, Ordering::Relaxed);
        if let Some(pc) = counters.cluster(cluster) {
            pc.prefetched.fetch_add(1, Ordering::Relaxed);
        }
        trace.instant(cluster, EventKind::Prefetch, key, (n * n) as u64);
        true
    } else {
        false
    };
    // a failed prefetch may have OOM-reclaimed tagged entries
    sync_directory(blas, router, cluster);
    resident
}

/// Serve one coalesced gemm batch: host path and un-pipelined device
/// path complete inline; the pipelined device path leaves the batch in
/// flight (executed, completion word posted) for the next iteration to
/// overlap against.
#[allow(clippy::too_many_arguments)]
fn serve_gemm(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    router: &PlacementRouter,
    plan: &FaultPlan,
    queue: &WorkQueue,
    trace: &TraceRecorder,
    launch_seq: &mut u64,
    batch: Vec<Job>,
    req: GemmRequest,
    target: ExecTarget,
    warm_b: bool,
    depth: usize,
    fuse: &mut FuseState,
    inflight: &mut Option<Inflight>,
    metrics_prev: &mut Metrics,
) {
    let t0 = Instant::now();
    let n = req.n;

    // ---- host path: no staging, no pipeline ----
    if target == ExecTarget::Host {
        if let Some(infl) = inflight.take() {
            finish_batch(
                blas, cluster, counters, router, plan, queue, trace, infl,
                fuse, metrics_prev,
            );
        }
        serve_gemm_host(
            blas, cluster, counters, trace, batch, req, t0, metrics_prev,
        );
        return;
    }
    let zero_copy = target == ExecTarget::DeviceZeroCopy;
    // one fault-schedule draw per staged launch attempt
    let seq = *launch_seq;
    *launch_seq += 1;

    // ---- synthesize every member's operands from its seeds ----
    let data: Vec<(Vec<f64>, Vec<f64>, Vec<f64>)> = batch
        .iter()
        .map(|j| match &j.payload {
            JobPayload::Gemm(r) => synth_gemm(&req, r.seed, r.b_seed),
            _ => unreachable!("gemm batch contains only gemm jobs"),
        })
        .collect();
    let queue_ms = queue_waits(&batch);

    // ---- stage (map-in): this is the region pipelining hides ----
    if inflight.is_none() {
        blas.reset_run(); // bound trace growth between pipeline drains
    }
    let inputs: Vec<(&[f64], &[f64], &[f64])> = data
        .iter()
        .map(|(a, b, c)| (a.as_slice(), b.as_slice(), c.as_slice()))
        .collect();
    let mut before = snap(blas);
    let mut stage = blas.gemm_batch_stage((n, n, n), 1.0, 0.0, &inputs, zero_copy);
    if stage.is_err() && inflight.is_some() {
        // the in-flight batch's operands may be what keeps us from
        // fitting: drain the pipeline and retry once serially
        let infl = inflight.take().expect("checked above");
        finish_batch(
            blas, cluster, counters, router, plan, queue, trace, infl,
            fuse, metrics_prev,
        );
        before = snap(blas); // re-baseline: the failed attempt + drain
                             // must not bill this batch
        stage = blas.gemm_batch_stage((n, n, n), 1.0, 0.0, &inputs, zero_copy);
    }
    let staged_run = match stage {
        Ok(s) => s,
        Err(e) => {
            // the failed staging may have OOM-reclaimed tagged entries:
            // keep the affinity directory honest before bailing
            sync_directory(blas, router, cluster);
            reply_error(counters, cluster, &batch, &e.to_string());
            return;
        }
    };
    drop(inputs);
    let stage_acct = delta(before, snap(blas));

    // ---- cancel-after-stage: every member's submitter stopped waiting
    // while the batch staged — release the operand-cache pins and
    // map(alloc:) outputs instead of launching for dropped receivers ----
    if batch.iter().all(|j| j.cancel.is_cancelled()) {
        counters.cancelled.fetch_add(batch.len() as u64, Ordering::Relaxed);
        inflight_sub(counters, cluster, batch.len() as u64);
        blas.gemm_batch_abandon(staged_run);
        sync_directory(blas, router, cluster);
        if inflight.is_none() {
            check_pins_drained(blas, counters, cluster);
        }
        return;
    }

    // ---- injected staging/DMA fault: abandon exactly like the cancel
    // path above (pins and map(alloc:) outputs released), drain the
    // pipeline to a quiesce point, then recover every member ----
    if plan.staging_fault(cluster, seq) {
        counters.faults_injected.fetch_add(1, Ordering::Relaxed);
        blas.gemm_batch_abandon(staged_run);
        sync_directory(blas, router, cluster);
        if let Some(infl) = inflight.take() {
            finish_batch(
                blas, cluster, counters, router, plan, queue, trace, infl,
                fuse, metrics_prev,
            );
        }
        handle_fault(
            blas, cluster, counters, router, plan, queue, trace, batch,
            FaultKind::StagingDma, metrics_prev,
        );
        check_pins_drained(blas, counters, cluster);
        return;
    }

    // ---- affinity bookkeeping: tracked B operands now resident here ----
    if router.affinity_enabled() {
        let b_keys = blas.gemm_staged_b_keys(&staged_run);
        for (job, ck) in batch.iter().zip(b_keys) {
            let JobPayload::Gemm(r) = &job.payload else { continue };
            let (Some(bs), Some(ck)) = (r.b_seed, ck) else { continue };
            let key = operand_key("gemm_b", n, bs);
            blas.engine.opcache.set_tag(&ck, key);
            router.note_resident(key, cluster);
        }
    }

    // ---- overlap credit (model-accounted), then drain the previous batch ----
    let mut hidden = 0u64;
    let mut pipelined = false;
    if let Some(infl) = inflight.take() {
        hidden = overlap_credit(blas, stage_acct.data_copy, infl.acct.compute);
        pipelined = true;
        finish_batch(
            blas, cluster, counters, router, plan, queue, trace, infl,
            fuse, metrics_prev,
        );
        // the drained batch is fully accounted and this batch's stage
        // delta is already materialized: safe to bound trace growth now
        // (everything after re-snapshots from the cleared trace)
        blas.reset_run();
    }

    // ---- execute (doorbell + compute; completion word posted) ----
    let before = snap(blas);
    let exec_at = Instant::now();
    let run = match blas.gemm_batch_execute(staged_run) {
        Ok(r) => r,
        Err(e) => {
            // the overlap credit is dropped with the batch: never report
            // hidden map-in for work that produced no results
            sync_directory(blas, router, cluster);
            reply_error(counters, cluster, &batch, &e.to_string());
            return;
        }
    };
    if pipelined {
        counters.pipelined_batches.fetch_add(1, Ordering::Relaxed);
        counters
            .overlap_hidden_us
            .fetch_add(virt_us(blas, hidden), Ordering::Relaxed);
    }
    let mut acct = stage_acct;
    acct.add(delta(before, snap(blas)));
    acct.hidden = hidden;
    acct.warm_b = warm_b;

    // ---- fault plan: execute-time seams + the completion deadline ----
    let fault = launch_fault(plan, counters, cluster, seq);
    let deadline = completion_deadline(blas, plan, exec_at, |cm| {
        cm.offload_gemm_cycles((n, n, n), batch.len(), warm_b, true)
    });

    let infl = Inflight {
        jobs: batch,
        run: InflightRun::Gemm { req, data, run },
        acct,
        queue_ms,
        work_us: t0.elapsed().as_micros() as u64,
        collected_at: t0,
        exec_at,
        fault,
        deadline,
    };
    if depth >= 2 {
        *inflight = Some(infl); // finished when the next job (or none) arrives
    } else {
        finish_batch(
            blas, cluster, counters, router, plan, queue, trace, infl,
            fuse, metrics_prev,
        );
    }
}

/// Serve one coalesced gemv batch: the level-2 twin of [`serve_gemm`] —
/// host path inline, device path staged/executed and (when pipelining
/// is on) left in flight for the next batch to overlap against.
#[allow(clippy::too_many_arguments)]
fn serve_gemv(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    router: &PlacementRouter,
    plan: &FaultPlan,
    queue: &WorkQueue,
    trace: &TraceRecorder,
    launch_seq: &mut u64,
    batch: Vec<Job>,
    req: GemvRequest,
    depth: usize,
    fuse: &mut FuseState,
    inflight: &mut Option<Inflight>,
    metrics_prev: &mut Metrics,
) {
    let t0 = Instant::now();
    let (m, n) = (req.m, req.n);
    blas.policy.mode = req.mode;

    // synthesize (A, x) per member; y starts at zero
    let data: Vec<(Vec<f64>, Vec<f64>)> = batch
        .iter()
        .map(|j| {
            let JobPayload::Gemv(r) = &j.payload else {
                unreachable!("gemv batch contains only gemv jobs")
            };
            let mut rng = Rng::new(r.seed);
            (rng.normal_vec(m * n), rng.normal_vec(n))
        })
        .collect();
    let queue_ms = queue_waits(&batch);

    // ---- host path: no staging, no pipeline ----
    if blas.policy.gemv(m, n) == ExecTarget::Host {
        if let Some(infl) = inflight.take() {
            finish_batch(
                blas, cluster, counters, router, plan, queue, trace, infl,
                fuse, metrics_prev,
            );
        }
        serve_gemv_host(
            blas, cluster, counters, trace, batch, req, data, t0, metrics_prev,
        );
        return;
    }
    let zero_copy = blas.policy.gemv(m, n) == ExecTarget::DeviceZeroCopy;
    let ys: Vec<Vec<f64>> = vec![vec![0.0; m]; batch.len()];
    // one fault-schedule draw per staged launch attempt
    let seq = *launch_seq;
    *launch_seq += 1;

    // ---- stage (map-in) ----
    if inflight.is_none() {
        blas.reset_run();
    }
    let inputs: Vec<(&[f64], &[f64], &[f64])> = data
        .iter()
        .zip(ys.iter())
        .map(|((a, x), y)| (a.as_slice(), x.as_slice(), y.as_slice()))
        .collect();
    let mut before = snap(blas);
    let mut stage = blas.gemv_batch_stage((m, n), 1.0, 0.0, &inputs, zero_copy);
    if stage.is_err() && inflight.is_some() {
        let infl = inflight.take().expect("checked above");
        finish_batch(
            blas, cluster, counters, router, plan, queue, trace, infl,
            fuse, metrics_prev,
        );
        before = snap(blas);
        stage = blas.gemv_batch_stage((m, n), 1.0, 0.0, &inputs, zero_copy);
    }
    let staged_run = match stage {
        Ok(s) => s,
        Err(e) => {
            sync_directory(blas, router, cluster);
            reply_error(counters, cluster, &batch, &e.to_string());
            return;
        }
    };
    drop(inputs);
    drop(data); // staged: the batch state owns the padded copies now
    let stage_acct = delta(before, snap(blas));

    // ---- cancel-after-stage (see serve_gemm) ----
    if batch.iter().all(|j| j.cancel.is_cancelled()) {
        counters.cancelled.fetch_add(batch.len() as u64, Ordering::Relaxed);
        inflight_sub(counters, cluster, batch.len() as u64);
        blas.gemv_batch_abandon(staged_run);
        sync_directory(blas, router, cluster);
        if inflight.is_none() {
            check_pins_drained(blas, counters, cluster);
        }
        return;
    }

    // ---- injected staging/DMA fault (see serve_gemm) ----
    if plan.staging_fault(cluster, seq) {
        counters.faults_injected.fetch_add(1, Ordering::Relaxed);
        blas.gemv_batch_abandon(staged_run);
        sync_directory(blas, router, cluster);
        if let Some(infl) = inflight.take() {
            finish_batch(
                blas, cluster, counters, router, plan, queue, trace, infl,
                fuse, metrics_prev,
            );
        }
        handle_fault(
            blas, cluster, counters, router, plan, queue, trace, batch,
            FaultKind::StagingDma, metrics_prev,
        );
        check_pins_drained(blas, counters, cluster);
        return;
    }

    // ---- overlap credit (model-accounted), then drain the previous batch ----
    let mut hidden = 0u64;
    let mut pipelined = false;
    if let Some(infl) = inflight.take() {
        hidden = overlap_credit(blas, stage_acct.data_copy, infl.acct.compute);
        pipelined = true;
        finish_batch(
            blas, cluster, counters, router, plan, queue, trace, infl,
            fuse, metrics_prev,
        );
        blas.reset_run();
    }

    // ---- execute ----
    let before = snap(blas);
    let exec_at = Instant::now();
    let run = match blas.gemv_batch_execute(staged_run) {
        Ok(r) => r,
        Err(e) => {
            sync_directory(blas, router, cluster);
            reply_error(counters, cluster, &batch, &e.to_string());
            return;
        }
    };
    if pipelined {
        counters.pipelined_batches.fetch_add(1, Ordering::Relaxed);
        counters
            .overlap_hidden_us
            .fetch_add(virt_us(blas, hidden), Ordering::Relaxed);
    }
    let mut acct = stage_acct;
    acct.add(delta(before, snap(blas)));
    acct.hidden = hidden;

    // ---- fault plan: execute-time seams + the completion deadline ----
    let fault = launch_fault(plan, counters, cluster, seq);
    let deadline = completion_deadline(blas, plan, exec_at, |cm| {
        cm.offload_gemv_cycles((m, n), ys.len(), true)
    });

    let infl = Inflight {
        jobs: batch,
        run: InflightRun::Gemv { req, ys, run },
        acct,
        queue_ms,
        work_us: t0.elapsed().as_micros() as u64,
        collected_at: t0,
        exec_at,
        fault,
        deadline,
    };
    if depth >= 2 {
        *inflight = Some(infl);
    } else {
        finish_batch(
            blas, cluster, counters, router, plan, queue, trace, infl,
            fuse, metrics_prev,
        );
    }
}

/// Serve one chain job.  The chained device path stages the whole
/// dependent sequence as ONE submission (fork once, intermediates
/// device-resident) and rides the software pipeline exactly like a gemm
/// batch; `chained = false` or a host decision runs the same links as
/// separate per-op calls through the ordinary dispatch — the oracle the
/// chained checksums must match bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn serve_chain(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    router: &PlacementRouter,
    plan: &FaultPlan,
    queue: &WorkQueue,
    trace: &TraceRecorder,
    launch_seq: &mut u64,
    job: Job,
    req: ChainRequest,
    depth: usize,
    fuse: &mut FuseState,
    inflight: &mut Option<Inflight>,
    metrics_prev: &mut Metrics,
) {
    let t0 = Instant::now();
    blas.policy.mode = req.mode;
    let m = req.m;
    let dims = req.dims.clone();
    let links = req.links();
    if links == 0 || dims.iter().any(|&d| d == 0) {
        reply_error(counters, cluster, &[job], "chain: empty or zero-width spec");
        return;
    }
    let n_last = dims[links];
    let batch = vec![job];
    let queue_ms = queue_waits(&batch);

    // ---- synthesize the activation and every link's weights ----
    let mut rng = Rng::new(req.seed);
    let x = rng.normal_vec(m * dims[0]);
    let weights: Vec<Vec<f64>> = dims
        .windows(2)
        .zip(req.b_seeds.iter())
        .map(|(w, bs)| match bs {
            Some(bs) => Rng::new(*bs).normal_vec(w[0] * w[1]),
            None => rng.normal_vec(w[0] * w[1]),
        })
        .collect();

    // ---- per-op oracle / host path: no chain staging, no pipeline ----
    let target = blas.policy.chain(m, &dims);
    if !req.chained || target == ExecTarget::Host {
        if let Some(infl) = inflight.take() {
            finish_batch(
                blas, cluster, counters, router, plan, queue, trace, infl,
                fuse, metrics_prev,
            );
        }
        serve_chain_unchained(
            blas, cluster, counters, router, trace, batch, &req, x, &weights,
            t0, metrics_prev,
        );
        return;
    }
    // one fault-schedule draw per staged launch attempt
    let seq = *launch_seq;
    *launch_seq += 1;

    // ---- stage: fork once, input + weights + every output resident ----
    if inflight.is_none() {
        blas.reset_run();
    }
    let specs: Vec<ChainLink<'_, f64>> = dims
        .windows(2)
        .zip(weights.iter())
        .map(|(w, b)| ChainLink {
            b: b.as_slice(),
            dims: (w[0], w[1]),
            bias: None,
            relu: false,
        })
        .collect();
    let mut before = snap(blas);
    let mut stage = blas.chain_stage(m, &x, &specs);
    if stage.is_err() && inflight.is_some() {
        // the in-flight batch's operands may be what keeps the chain
        // from fitting: drain the pipeline and retry once serially
        let infl = inflight.take().expect("checked above");
        finish_batch(
            blas, cluster, counters, router, plan, queue, trace, infl,
            fuse, metrics_prev,
        );
        before = snap(blas);
        stage = blas.chain_stage(m, &x, &specs);
    }
    let staged_run = match stage {
        Ok(s) => s,
        Err(e) => {
            sync_directory(blas, router, cluster);
            reply_error(counters, cluster, &batch, &e.to_string());
            return;
        }
    };
    let stage_acct = delta(before, snap(blas));

    // ---- cancel-after-stage: the submitter stopped waiting while the
    // chain staged — release the operand-cache pins and map(alloc:)
    // outputs instead of launching for a dropped receiver ----
    if batch[0].cancel.is_cancelled() {
        blas.chain_abandon(staged_run);
        counters.cancelled.fetch_add(1, Ordering::Relaxed);
        inflight_sub(counters, cluster, 1);
        sync_directory(blas, router, cluster);
        if inflight.is_none() {
            check_pins_drained(blas, counters, cluster);
        }
        return;
    }

    // ---- injected staging/DMA fault (see serve_gemm) ----
    if plan.staging_fault(cluster, seq) {
        counters.faults_injected.fetch_add(1, Ordering::Relaxed);
        blas.chain_abandon(staged_run);
        sync_directory(blas, router, cluster);
        if let Some(infl) = inflight.take() {
            finish_batch(
                blas, cluster, counters, router, plan, queue, trace, infl,
                fuse, metrics_prev,
            );
        }
        handle_fault(
            blas, cluster, counters, router, plan, queue, trace, batch,
            FaultKind::StagingDma, metrics_prev,
        );
        check_pins_drained(blas, counters, cluster);
        return;
    }

    // ---- affinity bookkeeping: tracked shared weights resident here ----
    if router.affinity_enabled() {
        let b_keys = blas.chain_staged_b_keys(&staged_run);
        for ((w, bs), ck) in dims.windows(2).zip(req.b_seeds.iter()).zip(b_keys) {
            let (Some(bs), Some(ck)) = (bs, ck) else { continue };
            let key = chain_b_key(w[0], w[1], *bs);
            blas.engine.opcache.set_tag(&ck, key);
            router.note_resident(key, cluster);
        }
    }

    // ---- overlap credit, then drain the previous batch ----
    let mut hidden = 0u64;
    let mut pipelined = false;
    if let Some(infl) = inflight.take() {
        hidden = overlap_credit(blas, stage_acct.data_copy, infl.acct.compute);
        pipelined = true;
        finish_batch(
            blas, cluster, counters, router, plan, queue, trace, infl,
            fuse, metrics_prev,
        );
        blas.reset_run();
    }

    // ---- execute: one doorbell runs every link ----
    let before = snap(blas);
    let exec_at = Instant::now();
    let run = match blas.chain_execute(staged_run) {
        Ok(r) => r,
        Err(e) => {
            sync_directory(blas, router, cluster);
            reply_error(counters, cluster, &batch, &e.to_string());
            return;
        }
    };
    // one link-boundary marker per dependent gemm in the fused launch
    // (a = link index, b = the link's output width)
    for (i, w) in dims.windows(2).enumerate() {
        trace.instant(cluster, EventKind::ChainLink, i as u64, w[1] as u64);
    }
    if pipelined {
        counters.pipelined_batches.fetch_add(1, Ordering::Relaxed);
        counters
            .overlap_hidden_us
            .fetch_add(virt_us(blas, hidden), Ordering::Relaxed);
    }
    let mut acct = stage_acct;
    acct.add(delta(before, snap(blas)));
    acct.hidden = hidden;

    // ---- fault plan: execute-time seams + the completion deadline ----
    let fault = launch_fault(plan, counters, cluster, seq);
    let deadline = completion_deadline(blas, plan, exec_at, |cm| {
        cm.offload_chain_cycles(m, &dims)
    });

    let infl = Inflight {
        jobs: batch,
        run: InflightRun::Chain { req, out: vec![0.0; m * n_last], run },
        acct,
        queue_ms,
        work_us: t0.elapsed().as_micros() as u64,
        collected_at: t0,
        exec_at,
        fault,
        deadline,
    };
    if depth >= 2 {
        *inflight = Some(infl);
    } else {
        finish_batch(
            blas, cluster, counters, router, plan, queue, trace, infl,
            fuse, metrics_prev,
        );
    }
}

/// The per-op chain oracle: run every link as a separate `gemm` through
/// the ordinary dispatch (each link pays its own fork-join and its
/// intermediate round-trips through the host) — identical numerics to
/// the chained path, none of the elision.  Also serves host-decided
/// chains: below the chain crossover each link simply dispatches itself.
#[allow(clippy::too_many_arguments)]
fn serve_chain_unchained(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    router: &PlacementRouter,
    trace: &TraceRecorder,
    batch: Vec<Job>,
    req: &ChainRequest,
    x: Vec<f64>,
    weights: &[Vec<f64>],
    t0: Instant,
    metrics_prev: &mut Metrics,
) {
    let m = req.m;
    let queue_ms = queue_waits(&batch);
    blas.reset_run();
    let before = snap(blas);
    let exec_at = Instant::now();
    let mut h = x;
    for (i, (w, b)) in req.dims.windows(2).zip(weights).enumerate() {
        let (k, n) = (w[0], w[1]);
        let mut c = vec![0.0; m * n];
        let r = blas.gemm(
            crate::blas::Transpose::No,
            crate::blas::Transpose::No,
            1.0,
            &h,
            (m, k),
            b,
            (k, n),
            0.0,
            &mut c,
            (m, n),
        );
        match r {
            Ok(()) => {
                trace.instant(cluster, EventKind::ChainLink, i as u64, n as u64);
                h = c
            }
            Err(e) => {
                sync_directory(blas, router, cluster);
                reply_error(counters, cluster, &batch, &e.to_string());
                return;
            }
        }
    }
    let done_at = Instant::now();
    sync_directory(blas, router, cluster);
    let checksum = h.iter().sum::<f64>();
    let acct = delta(before, snap(blas));
    send_outcomes(
        blas,
        cluster,
        counters,
        trace,
        &batch,
        "chain",
        (m, *req.dims.last().expect("non-empty dims")),
        req.mode,
        &[checksum],
        acct,
        &queue_ms,
        t0.elapsed().as_micros() as u64,
        BatchMarks { collected_at: t0, exec_at, done_at },
        Some(&req.dims),
        None,
        metrics_prev,
    );
}

/// Synthesize a DAG request's per-node weights and biases from its
/// seeds, in the fixed stream order every path must reproduce (device,
/// host oracle and fault fallback): per node in index order, weight
/// first (its own `b_seed` stream, or the continuing request stream),
/// then bias.  Non-matmul (fan-in) nodes draw nothing.
fn synth_dag_operands(
    shape: &DagShape,
    b_seeds: &[Option<u64>],
    rng: &mut Rng,
) -> (Vec<Option<Vec<f64>>>, Vec<Option<Vec<f64>>>) {
    let widths = shape.widths();
    let mut weights = Vec::with_capacity(shape.nodes.len());
    let mut biases = Vec::with_capacity(shape.nodes.len());
    for (i, node) in shape.nodes.iter().enumerate() {
        weights.push(node.op.is_matmul().then(|| {
            let len = shape.in_width(i) * widths[i];
            match b_seeds.get(i).copied().flatten() {
                Some(bs) => Rng::new(bs).normal_vec(len),
                None => rng.normal_vec(len),
            }
        }));
        biases.push(node.bias.then(|| rng.normal_vec(widths[i])));
    }
    (weights, biases)
}

/// Serve one DAG job.  The device path stages the whole graph as ONE
/// submission (fork once, interior edges device-resident, a fan-out
/// trunk staged exactly once) and rides the software pipeline exactly
/// like a chain; a host decision runs the same nodes through the per-op
/// host walk — the oracle the staged checksums must match bit-for-bit.
/// A request carrying `input_key` splices onto the previous DAG's
/// still-published output instead of synthesizing its input
/// (cross-request fusion); one carrying `publish_key` leaves its final
/// sink behind for the next request's splice.
#[allow(clippy::too_many_arguments)]
fn serve_dag(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    router: &PlacementRouter,
    plan: &FaultPlan,
    queue: &WorkQueue,
    trace: &TraceRecorder,
    launch_seq: &mut u64,
    job: Job,
    req: DagRequest,
    depth: usize,
    fuse: &mut FuseState,
    inflight: &mut Option<Inflight>,
    metrics_prev: &mut Metrics,
) {
    let t0 = Instant::now();
    blas.policy.mode = req.mode;
    let shape = req.shape.clone();
    let m = shape.m;
    if shape.nodes.is_empty() || m == 0 || shape.d0 == 0 {
        reply_error(counters, cluster, &[job], "dag: empty or zero-dim spec");
        return;
    }
    let widths = shape.widths();
    let batch = vec![job];
    let queue_ms = queue_waits(&batch);

    // ---- cross-request fusion: resolve the producer's published output
    // BEFORE any synthesis — the input either splices or the request
    // fails fast (re-synthesizing from the seed would silently change
    // the numerics the submitter asked for) ----
    let fused_x = match req.input_key {
        None => None,
        Some(key) => {
            let now = Instant::now();
            if fuse.slot.as_ref().is_some_and(|s| now >= s.expires_at) {
                let stale = fuse.slot.take().expect("checked above");
                router.note_evicted(dag_fuse_key(stale.key), cluster);
            }
            let hit = fuse.slot.as_ref().is_some_and(|s| {
                s.key == key && s.rows == m && s.width == shape.d0
            });
            if !hit {
                reply_error(
                    counters,
                    cluster,
                    &batch,
                    &format!(
                        "dag: input_key {key} has no resident producer \
                         output on this worker (fuse window expired or \
                         never published)"
                    ),
                );
                return;
            }
            let slot = fuse.slot.take().expect("checked above");
            // consumed: the directory must stop steering at it
            router.note_evicted(dag_fuse_key(slot.key), cluster);
            counters.dag_fused_requests.fetch_add(1, Ordering::Relaxed);
            trace.instant(
                cluster,
                EventKind::DagFuse,
                dag_fuse_key(key),
                (m * shape.d0 * 8) as u64,
            );
            Some(slot.data)
        }
    };

    // ---- synthesize the input and every node's operands ----
    let mut rng = Rng::new(req.seed);
    let x = match fused_x {
        Some(d) => d,
        // a fused request never draws its input; its weights still
        // continue from the stream's start, so the same spec computes
        // the same function whichever way the input arrived
        None => rng.normal_vec(m * shape.d0),
    };
    let (weights, biases) = synth_dag_operands(&shape, &req.b_seeds, &mut rng);
    let specs: Vec<DagNode<'_, f64>> = weights
        .iter()
        .zip(biases.iter())
        .map(|(w, b)| DagNode { b: w.as_deref(), bias: b.as_deref() })
        .collect();

    // ---- host / per-op oracle path: no graph staging, no pipeline ----
    if blas.policy.dag(&shape) == ExecTarget::Host {
        if let Some(infl) = inflight.take() {
            finish_batch(
                blas, cluster, counters, router, plan, queue, trace, infl,
                fuse, metrics_prev,
            );
        }
        serve_dag_host(
            blas, cluster, counters, router, trace, batch, &req, &shape, x,
            &specs, t0, metrics_prev,
        );
        return;
    }
    // one fault-schedule draw per staged launch attempt
    let seq = *launch_seq;
    *launch_seq += 1;

    // ---- stage: fork once, input + weights + every node output
    // resident (a fan-out trunk's buffer staged exactly once) ----
    if inflight.is_none() {
        blas.reset_run();
    }
    let mut before = snap(blas);
    let mut stage = blas.dag_stage(&shape, &x, &specs);
    if stage.is_err() && inflight.is_some() {
        // the in-flight batch's operands may be what keeps the graph
        // from fitting: drain the pipeline and retry once serially
        let infl = inflight.take().expect("checked above");
        finish_batch(
            blas, cluster, counters, router, plan, queue, trace, infl,
            fuse, metrics_prev,
        );
        before = snap(blas);
        stage = blas.dag_stage(&shape, &x, &specs);
    }
    let staged_run = match stage {
        Ok(s) => s,
        Err(e) => {
            sync_directory(blas, router, cluster);
            reply_error(counters, cluster, &batch, &e.to_string());
            return;
        }
    };
    let stage_acct = delta(before, snap(blas));

    // ---- cancel-after-stage: release the pins (the fan-out trunk's
    // multi-consumer pin included) instead of launching for a dropped
    // receiver ----
    if batch[0].cancel.is_cancelled() {
        blas.dag_abandon(staged_run);
        counters.cancelled.fetch_add(1, Ordering::Relaxed);
        inflight_sub(counters, cluster, 1);
        sync_directory(blas, router, cluster);
        if inflight.is_none() {
            check_pins_drained(blas, counters, cluster);
        }
        return;
    }

    // ---- injected staging/DMA fault (see serve_gemm) ----
    if plan.staging_fault(cluster, seq) {
        counters.faults_injected.fetch_add(1, Ordering::Relaxed);
        blas.dag_abandon(staged_run);
        sync_directory(blas, router, cluster);
        if let Some(infl) = inflight.take() {
            finish_batch(
                blas, cluster, counters, router, plan, queue, trace, infl,
                fuse, metrics_prev,
            );
        }
        handle_fault(
            blas, cluster, counters, router, plan, queue, trace, batch,
            FaultKind::StagingDma, metrics_prev,
        );
        check_pins_drained(blas, counters, cluster);
        return;
    }

    // ---- affinity bookkeeping: tracked shared weights resident here
    // (same keyspace as chain links, so a DAG's weight warms a chain's
    // placement and vice versa) ----
    if router.affinity_enabled() {
        let b_keys = blas.dag_staged_b_keys(&staged_run);
        for (i, ck) in b_keys.into_iter().enumerate() {
            let (Some(bs), Some(ck)) =
                (req.b_seeds.get(i).copied().flatten(), ck)
            else {
                continue;
            };
            let key = chain_b_key(shape.in_width(i), widths[i], bs);
            blas.engine.opcache.set_tag(&ck, key);
            router.note_resident(key, cluster);
        }
    }

    // ---- overlap credit, then drain the previous batch ----
    let mut hidden = 0u64;
    let mut pipelined = false;
    if let Some(infl) = inflight.take() {
        hidden = overlap_credit(blas, stage_acct.data_copy, infl.acct.compute);
        pipelined = true;
        finish_batch(
            blas, cluster, counters, router, plan, queue, trace, infl,
            fuse, metrics_prev,
        );
        blas.reset_run();
    }

    // ---- execute: one doorbell runs every node in topological order ----
    let before = snap(blas);
    let exec_at = Instant::now();
    let run = match blas.dag_execute(staged_run) {
        Ok(r) => r,
        Err(e) => {
            sync_directory(blas, router, cluster);
            reply_error(counters, cluster, &batch, &e.to_string());
            return;
        }
    };
    if pipelined {
        counters.pipelined_batches.fetch_add(1, Ordering::Relaxed);
        counters
            .overlap_hidden_us
            .fetch_add(virt_us(blas, hidden), Ordering::Relaxed);
    }
    let mut acct = stage_acct;
    acct.add(delta(before, snap(blas)));
    acct.hidden = hidden;

    // ---- fault plan: execute-time seams + the completion deadline ----
    let fault = launch_fault(plan, counters, cluster, seq);
    let deadline = completion_deadline(blas, plan, exec_at, |cm| {
        cm.offload_dag_cycles(&shape)
    });

    let outs: Vec<Vec<f64>> = shape
        .sinks()
        .iter()
        .map(|&s| {
            let (r, c) = shape.out_dims(s);
            vec![0.0; r * c]
        })
        .collect();
    let infl = Inflight {
        jobs: batch,
        run: InflightRun::Dag { req, outs, run },
        acct,
        queue_ms,
        work_us: t0.elapsed().as_micros() as u64,
        collected_at: t0,
        exec_at,
        fault,
        deadline,
    };
    if depth >= 2 {
        *inflight = Some(infl);
    } else {
        finish_batch(
            blas, cluster, counters, router, plan, queue, trace, infl,
            fuse, metrics_prev,
        );
    }
}

/// The per-node DAG host oracle: run every node through the host walk —
/// identical numerics to the staged device path, none of the residency.
/// `blas.dag` is pinned to its host arm for the duration so a
/// concurrent calibration update can never flip the already-made
/// decision mid-request.
#[allow(clippy::too_many_arguments)]
fn serve_dag_host(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    router: &PlacementRouter,
    trace: &TraceRecorder,
    batch: Vec<Job>,
    req: &DagRequest,
    shape: &DagShape,
    x: Vec<f64>,
    specs: &[DagNode<'_, f64>],
    t0: Instant,
    metrics_prev: &mut Metrics,
) {
    let queue_ms = queue_waits(&batch);
    blas.reset_run();
    let before = snap(blas);
    let exec_at = Instant::now();
    let sinks = shape.sinks();
    let mut outs: Vec<Vec<f64>> = sinks
        .iter()
        .map(|&s| {
            let (r, c) = shape.out_dims(s);
            vec![0.0; r * c]
        })
        .collect();
    let saved_mode = blas.policy.mode;
    blas.policy.mode = crate::config::DispatchMode::HostOnly;
    let result = {
        let mut refs: Vec<&mut [f64]> =
            outs.iter_mut().map(|o| o.as_mut_slice()).collect();
        blas.dag(shape, &x, specs, &mut refs)
    };
    blas.policy.mode = saved_mode;
    let done_at = Instant::now();
    sync_directory(blas, router, cluster);
    match result {
        Ok(()) => {
            let checksum: f64 =
                outs.iter().map(|o| o.iter().sum::<f64>()).sum();
            let acct = delta(before, snap(blas));
            let (rm, rn) =
                shape.out_dims(*sinks.last().expect("non-empty dag"));
            send_outcomes(
                blas,
                cluster,
                counters,
                trace,
                &batch,
                "dag",
                (rm, rn),
                req.mode,
                &[checksum],
                acct,
                &queue_ms,
                t0.elapsed().as_micros() as u64,
                BatchMarks { collected_at: t0, exec_at, done_at },
                None,
                Some((shape, &[][..])),
                metrics_prev,
            );
        }
        Err(e) => {
            reply_error(counters, cluster, &batch, &e.to_string());
        }
    }
}

/// Error replies for every member of a failed batch, with the failure
/// counted once per member and the launch attempt counted as a batch.
fn reply_error(counters: &SchedCounters, cluster: u32, batch: &[Job], msg: &str) {
    counters.failed.fetch_add(batch.len() as u64, Ordering::Relaxed);
    counters.batches.fetch_add(1, Ordering::Relaxed);
    if let Some(pc) = counters.cluster(cluster) {
        pc.batches.fetch_add(1, Ordering::Relaxed);
    }
    inflight_sub(counters, cluster, batch.len() as u64);
    for job in batch {
        let _ = job.reply.send(Err(msg.to_string()));
    }
}

/// Host-path gemm batch: one host kernel per member, no offload.
#[allow(clippy::too_many_arguments)]
fn serve_gemm_host(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    trace: &TraceRecorder,
    batch: Vec<Job>,
    req: GemmRequest,
    t0: Instant,
    metrics_prev: &mut Metrics,
) {
    let n = req.n;
    let queue_ms = queue_waits(&batch);
    blas.reset_run();
    let before = snap(blas);
    let exec_at = Instant::now();
    let mut checksums = Vec::with_capacity(batch.len());
    for job in &batch {
        let JobPayload::Gemm(r) = &job.payload else {
            unreachable!("gemm batch contains only gemm jobs")
        };
        let (a, b, mut c) = synth_gemm(&req, r.seed, r.b_seed);
        let r = blas.gemm(
            crate::blas::Transpose::No,
            crate::blas::Transpose::No,
            1.0,
            &a,
            (n, n),
            &b,
            (n, n),
            0.0,
            &mut c,
            (n, n),
        );
        match r {
            Ok(()) => checksums.push(c.iter().sum::<f64>()),
            Err(e) => {
                reply_error(counters, cluster, &batch, &e.to_string());
                return;
            }
        }
    }
    let done_at = Instant::now();
    let acct = delta(before, snap(blas));
    send_outcomes(
        blas, cluster, counters, trace, &batch, "gemm", (n, n), req.mode,
        &checksums, acct, &queue_ms, t0.elapsed().as_micros() as u64,
        BatchMarks { collected_at: t0, exec_at, done_at }, None, None,
        metrics_prev,
    );
}

/// Host-path gemv batch: one host kernel per member, no offload.
#[allow(clippy::too_many_arguments)]
fn serve_gemv_host(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    trace: &TraceRecorder,
    batch: Vec<Job>,
    req: GemvRequest,
    data: Vec<(Vec<f64>, Vec<f64>)>,
    t0: Instant,
    metrics_prev: &mut Metrics,
) {
    let (m, n) = (req.m, req.n);
    let queue_ms = queue_waits(&batch);
    blas.reset_run();
    let before = snap(blas);
    let exec_at = Instant::now();
    let mut checksums = Vec::with_capacity(batch.len());
    for (a, x) in &data {
        let mut y = vec![0.0; m];
        let r = blas.gemv(
            crate::blas::Transpose::No, 1.0, a, (m, n), x, 0.0, &mut y,
        );
        match r {
            Ok(()) => checksums.push(y.iter().sum::<f64>()),
            Err(e) => {
                reply_error(counters, cluster, &batch, &e.to_string());
                return;
            }
        }
    }
    let done_at = Instant::now();
    let acct = delta(before, snap(blas));
    send_outcomes(
        blas, cluster, counters, trace, &batch, "gemv", (m, n), req.mode,
        &checksums, acct, &queue_ms, t0.elapsed().as_micros() as u64,
        BatchMarks { collected_at: t0, exec_at, done_at }, None, None,
        metrics_prev,
    );
}

/// Serve one coalesced level-1 batch (axpy or dot): synthesize each
/// member's vectors from its seed, dispatch through the policy (host
/// loop or ONE fork-join device launch for the whole batch), reply with
/// per-member checksums (axpy: sum of the updated y; dot: the scalar).
#[allow(clippy::too_many_arguments)]
fn serve_level1(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    router: &PlacementRouter,
    trace: &TraceRecorder,
    batch: Vec<Job>,
    req: Level1Request,
    metrics_prev: &mut Metrics,
) {
    let t0 = Instant::now();
    let n = req.n;
    let queue_ms = queue_waits(&batch);
    blas.policy.mode = req.mode;

    // synthesize (alpha, x, y) per member from its own request
    let data: Vec<(f64, Vec<f64>, Vec<f64>)> = batch
        .iter()
        .map(|j| {
            let JobPayload::Level1(r) = &j.payload else {
                unreachable!("level-1 batch contains only level-1 jobs")
            };
            let mut rng = Rng::new(r.seed);
            (r.alpha, rng.normal_vec(n), rng.normal_vec(n))
        })
        .collect();
    let kind = match req.op {
        Level1Op::Axpy => OffloadKind::Axpy,
        Level1Op::Dot => OffloadKind::Dot,
    };
    let out_len = if kind == OffloadKind::Axpy { n } else { 1 };
    let mut outs: Vec<Vec<f64>> = vec![vec![0.0; out_len]; batch.len()];

    blas.reset_run();
    let before = snap(blas);
    let exec_at = Instant::now();
    let result = {
        let inputs: Vec<(f64, &[f64], &[f64])> = data
            .iter()
            .map(|(a, x, y)| (*a, x.as_slice(), y.as_slice()))
            .collect();
        let mut out_refs: Vec<&mut [f64]> =
            outs.iter_mut().map(|o| o.as_mut_slice()).collect();
        blas.level1_batch(kind, &inputs, &mut out_refs)
    };
    let done_at = Instant::now();
    sync_directory(blas, router, cluster);
    let acct = delta(before, snap(blas));

    match result {
        Ok(()) => {
            let checksums: Vec<f64> = outs.iter().map(|o| o.iter().sum()).collect();
            send_outcomes(
                blas, cluster, counters, trace, &batch, req.op.name(), (1, n),
                req.mode, &checksums, acct, &queue_ms,
                t0.elapsed().as_micros() as u64,
                BatchMarks { collected_at: t0, exec_at, done_at }, None, None,
                metrics_prev,
            );
        }
        Err(e) => {
            reply_error(counters, cluster, &batch, &e.to_string());
        }
    }
}

/// Finish an executed batch: poll the mailbox completion word (posted at
/// execute time; the poll keeps the worker protocol-shaped for a backend
/// where compute genuinely overlaps the host), join, copy every member's
/// output back, release the mappings, and reply.
///
/// Fault handling: the poll runs under the batch's deadline — an expiry
/// while the word is pending marks the batch [`FaultKind::Deadline`]
/// (the worker keeps waiting: the simulated device always completes, and
/// the cleanup below must release its mappings).  A batch marked faulted
/// — injected at execute time or caught here — still runs its finish so
/// every mapping and pin is released, then discards the results and
/// routes every member into [`handle_fault`] instead of replying.
#[allow(clippy::too_many_arguments)]
fn finish_batch(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    router: &PlacementRouter,
    plan: &FaultPlan,
    queue: &WorkQueue,
    trace: &TraceRecorder,
    infl: Inflight,
    fuse: &mut FuseState,
    metrics_prev: &mut Metrics,
) {
    let mut fault = infl.fault;
    while !blas.offload_completion_pending() {
        if fault.is_none() {
            if let Some(dl) = infl.deadline {
                if Instant::now() >= dl {
                    // a real (non-injected) detector trip: not counted
                    // under faults_injected
                    fault = Some(FaultKind::Deadline);
                }
            }
        }
        std::thread::yield_now();
    }
    let t_finish = Instant::now();
    let before = snap(blas);

    let Inflight {
        jobs,
        run,
        acct: batch_acct,
        queue_ms,
        work_us,
        collected_at,
        exec_at,
        fault: _,
        deadline: _,
    } = infl;
    let marks = BatchMarks { collected_at, exec_at, done_at: t_finish };
    let (finish, checksums, op, dims, mode, chain_dims, dag_info) = match run {
        InflightRun::Gemm { req, mut data, run } => {
            let finish = {
                let mut outs: Vec<&mut [f64]> =
                    data.iter_mut().map(|(_, _, c)| c.as_mut_slice()).collect();
                blas.gemm_batch_finish(run, &mut outs)
            };
            let checksums: Vec<f64> =
                data.iter().map(|(_, _, c)| c.iter().sum()).collect();
            (finish, checksums, "gemm", (req.n, req.n), req.mode, None, None)
        }
        InflightRun::Gemv { req, mut ys, run } => {
            let finish = {
                let mut outs: Vec<&mut [f64]> =
                    ys.iter_mut().map(|y| y.as_mut_slice()).collect();
                blas.gemv_batch_finish(run, &mut outs)
            };
            let checksums: Vec<f64> = ys.iter().map(|y| y.iter().sum()).collect();
            (finish, checksums, "gemv", (req.m, req.n), req.mode, None, None)
        }
        InflightRun::Chain { req, mut out, run } => {
            // only the final link's output crosses back to the host; the
            // finish releases every intermediate's residency pin
            let finish = blas.chain_finish(run, &mut out);
            let checksum = out.iter().sum::<f64>();
            let n_last = *req.dims.last().expect("non-empty dims");
            (
                finish,
                vec![checksum],
                "chain",
                (req.m, n_last),
                req.mode,
                Some(req.dims),
                None,
            )
        }
        InflightRun::Dag { req, mut outs, run } => {
            // only the sink outputs cross back to the host; the finish
            // releases every interior edge's residency pin.  A faulted
            // DAG never publishes — its results are untrusted.
            let shape = req.shape.clone();
            let node_cycles = run.node_cycles().to_vec();
            let publish = req.publish_key.is_some() && fault.is_none();
            let finish = {
                let mut refs: Vec<&mut [f64]> =
                    outs.iter_mut().map(|o| o.as_mut_slice()).collect();
                blas.dag_finish(run, &mut refs, publish)
            };
            if finish.is_ok() && publish {
                let key = req.publish_key.expect("publish implies a key");
                let s = *shape.sinks().last().expect("non-empty dag");
                let (rows, width) = shape.out_dims(s);
                fuse.slot = Some(FuseSlot {
                    key,
                    rows,
                    width,
                    data: outs.last().cloned().unwrap_or_default(),
                    expires_at: t_finish
                        + Duration::from_millis(fuse.window_ms.max(1)),
                });
                // rendezvous: route the consumer that names this key here
                router.note_resident(dag_fuse_key(key), cluster);
            }
            let checksum: f64 =
                outs.iter().map(|o| o.iter().sum::<f64>()).sum();
            let s = *shape.sinks().last().expect("non-empty dag");
            let (rm, rn) = shape.out_dims(s);
            (
                finish,
                vec![checksum],
                "dag",
                (rm, rn),
                req.mode,
                None,
                Some((shape, node_cycles)),
            )
        }
    };
    let mut acct = batch_acct;
    acct.add(delta(before, snap(blas)));
    sync_directory(blas, router, cluster);

    // ---- faulted batch: mappings are released (the finish above ran
    // either way), results untrusted — discard and recover ----
    if let Some(kind) = fault {
        let _ = (finish, checksums, op, dims, mode, chain_dims, dag_info);
        handle_fault(
            blas, cluster, counters, router, plan, queue, trace, jobs, kind,
            metrics_prev,
        );
        return;
    }

    match finish {
        Ok(()) => {
            // active wall time only: stage+execute plus this finish —
            // excluding the in-flight idle gap under pipelining
            let service_us = work_us + t_finish.elapsed().as_micros() as u64;
            send_outcomes(
                blas,
                cluster,
                counters,
                trace,
                &jobs,
                op,
                dims,
                mode,
                &checksums,
                acct,
                &queue_ms,
                service_us,
                marks,
                chain_dims.as_deref(),
                dag_info.as_ref().map(|(s, nc)| (s, nc.as_slice())),
                metrics_prev,
            );
        }
        Err(e) => {
            reply_error(counters, cluster, &jobs, &e.to_string());
        }
    }
}

/// Recover a faulted batch: invalidate everything the failed cluster
/// held (operand cache, affinity residency, home overrides), report the
/// fault to the router's quarantine accounting, then resubmit every
/// member with bounded exponential backoff and a placement exclusion
/// bit for this cluster — or, when a member's attempts are exhausted,
/// no healthy target remains, or the queue closed, serve it inline on
/// the host BLAS path with `degraded: true` in the reply.
#[allow(clippy::too_many_arguments)]
fn handle_fault(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    router: &PlacementRouter,
    plan: &FaultPlan,
    queue: &WorkQueue,
    trace: &TraceRecorder,
    jobs: Vec<Job>,
    kind: FaultKind,
    metrics_prev: &mut Metrics,
) {
    // one fault event per faulted batch, whatever the seam or detector
    trace.instant(
        cluster,
        EventKind::FaultInjected,
        jobs.len() as u64,
        kind.trace_code(),
    );
    // the failed cluster's cached operands are suspect: drop every
    // unpinned entry, reclaim the DRAM, and clear the directory's view
    // so no later request steers at stale residency
    let bytes = blas.engine.invalidate_cache().unwrap_or(0);
    counters
        .cache_invalidated_bytes
        .fetch_add(bytes, Ordering::Relaxed);
    trace.instant(cluster, EventKind::CacheInvalidate, bytes, 0);
    sync_directory(blas, router, cluster);
    router.invalidate_cluster(cluster);
    if router.note_fault(cluster) {
        counters.quarantined.fetch_add(1, Ordering::Relaxed);
    }
    // the invalidation moved engine gauges (evictions, bytes in use):
    // absorb the delta so per-cluster metrics stay honest
    let metrics_now = blas.metrics();
    counters.absorb_engine_delta(cluster, metrics_prev, &metrics_now);
    *metrics_prev = metrics_now;

    let mut backed_off = false;
    for mut job in jobs {
        if job.cancel.is_cancelled() {
            counters.cancelled.fetch_add(1, Ordering::Relaxed);
            inflight_sub(counters, cluster, 1);
            continue;
        }
        job.fault
            .note(cluster, job.enqueued_at.elapsed().as_micros() as u64);
        let retry = job.fault.attempts < plan.max_attempts()
            && router.retry_targets_exist(job.fault.excluded)
            && !queue.is_closed();
        if !retry {
            host_fallback(
                blas, cluster, counters, router, trace, kind, job,
                metrics_prev,
            );
            continue;
        }
        if !backed_off {
            // one bounded-exponential pause per faulted batch, not per
            // member — the members shared the failed launch
            std::thread::sleep(Duration::from_millis(
                plan.backoff_ms(job.fault.attempts),
            ));
            backed_off = true;
        }
        inflight_sub(counters, cluster, 1);
        // the retry attempt re-measures its own queue/route spans; the
        // wall time the failed attempt consumed is already banked in
        // `job.fault.retry_us`
        job.spans = SpanStamps::default();
        job.enqueued_at = Instant::now();
        let (jid, attempts) = (job.id, job.fault.attempts as u64);
        match queue.push(job) {
            Ok(_) => {
                counters.retries.fetch_add(1, Ordering::Relaxed);
                trace.instant(cluster, EventKind::FaultRetry, jid, attempts);
                router.kick();
            }
            Err(_) => {
                // push consumes the job: its reply sender drops and the
                // submitter observes a failed request.  Only a queue
                // that filled or closed between the check and here.
                counters.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// `(op, (m, n), mode, checksum)` of one host-fallback execution.
type HostRun = std::result::Result<
    (&'static str, (usize, usize), crate::config::DispatchMode, f64),
    String,
>;

/// Last-resort recovery: run the job's op inline on the host BLAS path —
/// checksum-identical to the device path by construction — and reply
/// with `degraded: true` plus the faulted attempt count.  The dispatch
/// mode is forced to HostOnly for the duration so the fallback itself
/// can never launch on (and fault with) the device.
#[allow(clippy::too_many_arguments)]
fn host_fallback(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    router: &PlacementRouter,
    trace: &TraceRecorder,
    kind: FaultKind,
    job: Job,
    metrics_prev: &mut Metrics,
) {
    let t0 = Instant::now();
    let queue_wait_ms = job.enqueued_at.elapsed().as_secs_f64() * 1e3;
    let saved_mode = blas.policy.mode;
    blas.policy.mode = crate::config::DispatchMode::HostOnly;
    blas.reset_run();
    let before = snap(blas);
    let exec_at = Instant::now();
    let ran: HostRun = match &job.payload {
        JobPayload::Gemm(r) => {
            let n = r.n;
            let (a, b, mut c) = synth_gemm(r, r.seed, r.b_seed);
            blas.gemm(
                crate::blas::Transpose::No,
                crate::blas::Transpose::No,
                1.0,
                &a,
                (n, n),
                &b,
                (n, n),
                0.0,
                &mut c,
                (n, n),
            )
            .map(|_| ("gemm", (n, n), r.mode, c.iter().sum::<f64>()))
            .map_err(|e| e.to_string())
        }
        JobPayload::Gemv(r) => {
            let (m, n) = (r.m, r.n);
            let mut rng = Rng::new(r.seed);
            let a = rng.normal_vec(m * n);
            let x = rng.normal_vec(n);
            let mut y = vec![0.0; m];
            blas.gemv(crate::blas::Transpose::No, 1.0, &a, (m, n), &x, 0.0, &mut y)
                .map(|_| ("gemv", (m, n), r.mode, y.iter().sum::<f64>()))
                .map_err(|e| e.to_string())
        }
        JobPayload::Chain(r) => host_chain(blas, r),
        JobPayload::Dag(r) => host_dag(blas, r),
        // level-1 and fence jobs are never injected or deadlined
        _ => Err(format!(
            "fault recovery ({}): payload has no host fallback",
            kind.label()
        )),
    };
    blas.policy.mode = saved_mode;
    let acct = delta(before, snap(blas));
    sync_directory(blas, router, cluster);

    let (op, (m, n), mode, checksum) = match ran {
        Ok(v) => v,
        Err(e) => {
            reply_error(counters, cluster, std::slice::from_ref(&job), &e);
            return;
        }
    };
    let done_at = Instant::now();

    // counters before the reply, like every other completion path
    counters.host_fallbacks.fetch_add(1, Ordering::Relaxed);
    counters.completed.fetch_add(1, Ordering::Relaxed);
    counters.batches.fetch_add(1, Ordering::Relaxed);
    if let Some(pc) = counters.cluster(cluster) {
        pc.completed.fetch_add(1, Ordering::Relaxed);
        pc.batches.fetch_add(1, Ordering::Relaxed);
    }
    counters.note_service_us((t0.elapsed().as_micros() as u64).max(1));
    let metrics_now = blas.metrics();
    counters.absorb_engine_delta(cluster, metrics_prev, &metrics_now);
    *metrics_prev = metrics_now;
    inflight_sub(counters, cluster, 1);

    let f = blas.engine.freq_hz();
    let ms = |cycles: u64| Cycles(cycles).to_ns(f) / 1e6;
    let marks = BatchMarks { collected_at: t0, exec_at, done_at };
    let mut spans =
        SpanBreakdown::compute(job.enqueued_at, job.spans, marks, done_at);
    spans.retry_us = job.fault.retry_us;
    trace.instant(cluster, EventKind::HostFallback, job.id, kind.trace_code());
    record_job_spans(trace, cluster, &job, &spans, marks);
    counters.note_latency_us(op, cluster, spans.total_us);
    counters.note_span_us(
        spans.queue_us,
        spans.route_us,
        spans.linger_us,
        spans.stage_us,
        spans.execute_us,
        spans.finish_us,
    );
    if spans.retry_us > 0 {
        counters.note_retry_us(spans.retry_us);
    }
    let _ = job.reply.send(Ok(GemmOutcome {
        op,
        m,
        n,
        mode,
        checksum,
        data_copy_ms: ms(acct.data_copy),
        fork_join_ms: ms(acct.fork_join),
        compute_ms: ms(acct.compute),
        host_compute_ms: ms(acct.host_compute),
        total_ms: ms(
            acct.data_copy + acct.fork_join + acct.compute + acct.host_compute,
        ),
        cluster,
        batch_size: 1,
        queue_ms: queue_wait_ms,
        spans,
        degraded: true,
        attempts: job.fault.attempts,
    }));
}

/// Host-path chain for fault recovery: the same per-link loop as the
/// per-op oracle, with the same RNG call order as [`serve_chain`]'s
/// synthesis — the checksum matches the chained device path
/// bit-for-bit.
fn host_chain(blas: &mut HeroBlas, req: &ChainRequest) -> HostRun {
    let m = req.m;
    if req.links() == 0 || req.dims.iter().any(|&d| d == 0) {
        return Err("chain: empty or zero-width spec".to_string());
    }
    let mut rng = Rng::new(req.seed);
    let mut h = rng.normal_vec(m * req.dims[0]);
    for (w, bs) in req.dims.windows(2).zip(req.b_seeds.iter()) {
        let (k, n) = (w[0], w[1]);
        let b = match bs {
            Some(s) => Rng::new(*s).normal_vec(k * n),
            None => rng.normal_vec(k * n),
        };
        let mut c = vec![0.0; m * n];
        blas.gemm(
            crate::blas::Transpose::No,
            crate::blas::Transpose::No,
            1.0,
            &h,
            (m, k),
            &b,
            (k, n),
            0.0,
            &mut c,
            (m, n),
        )
        .map_err(|e| e.to_string())?;
        h = c;
    }
    let n_last = *req.dims.last().expect("non-empty dims");
    Ok(("chain", (m, n_last), req.mode, h.iter().sum::<f64>()))
}

/// Host-path DAG for fault recovery: the same host walk as the per-node
/// oracle, with the same RNG call order as [`serve_dag`]'s synthesis —
/// the checksum matches the staged device path bit-for-bit.  A fused
/// request cannot be recovered this way: its input was the producer's
/// resident output, which died with the faulted cluster.
fn host_dag(blas: &mut HeroBlas, req: &DagRequest) -> HostRun {
    let shape = &req.shape;
    let m = shape.m;
    if shape.nodes.is_empty() || m == 0 || shape.d0 == 0 {
        return Err("dag: empty or zero-dim spec".to_string());
    }
    if req.input_key.is_some() {
        return Err(
            "dag: fused request has no host fallback (the producer's \
             resident output was lost with the faulted cluster)"
                .to_string(),
        );
    }
    let mut rng = Rng::new(req.seed);
    let x = rng.normal_vec(m * shape.d0);
    let (weights, biases) = synth_dag_operands(shape, &req.b_seeds, &mut rng);
    let specs: Vec<DagNode<'_, f64>> = weights
        .iter()
        .zip(biases.iter())
        .map(|(w, b)| DagNode { b: w.as_deref(), bias: b.as_deref() })
        .collect();
    let sinks = shape.sinks();
    let mut outs: Vec<Vec<f64>> = sinks
        .iter()
        .map(|&s| {
            let (r, c) = shape.out_dims(s);
            vec![0.0; r * c]
        })
        .collect();
    {
        let mut refs: Vec<&mut [f64]> =
            outs.iter_mut().map(|o| o.as_mut_slice()).collect();
        blas.dag(shape, &x, &specs, &mut refs)
            .map_err(|e| e.to_string())?;
    }
    let (rm, rn) = shape.out_dims(*sinks.last().expect("non-empty dag"));
    let checksum: f64 = outs.iter().map(|o| o.iter().sum::<f64>()).sum();
    Ok(("dag", (rm, rn), req.mode, checksum))
}

/// Wall microseconds between two span-clock stamps (0 when reversed).
fn dur_us(from: Instant, to: Instant) -> u64 {
    to.saturating_duration_since(from).as_micros() as u64
}

/// Retrospective flight-recorder spans for one completed job: the five
/// telescoping `SpanBreakdown` stages, stored from the SAME instants
/// and durations the breakdown reports, so a `trace_dump` reconciles
/// exactly with the reply's `spans` object.
fn record_job_spans(
    trace: &TraceRecorder,
    cluster: u32,
    job: &Job,
    spans: &SpanBreakdown,
    marks: BatchMarks,
) {
    let routed_at = job.spans.routed_at.unwrap_or(job.enqueued_at);
    let claimed_at = job.spans.claimed_at.unwrap_or(routed_at);
    trace.span(
        cluster, EventKind::SpanQueue, job.enqueued_at, spans.queue_us, job.id,
    );
    trace.span(cluster, EventKind::SpanRoute, routed_at, spans.route_us, job.id);
    trace.span(cluster, EventKind::SpanStage, claimed_at, spans.stage_us, job.id);
    trace.span(
        cluster, EventKind::SpanExecute, marks.exec_at, spans.execute_us, job.id,
    );
    trace.span(
        cluster, EventKind::SpanFinish, marks.done_at, spans.finish_us, job.id,
    );
}

/// Counters + per-member outcome replies for one completed batch.
/// Uniform shapes => each member gets an even share of the batch's
/// virtual time; fork/join (and any pipelining credit) was accounted
/// once for all B.
#[allow(clippy::too_many_arguments)]
fn send_outcomes(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    trace: &TraceRecorder,
    batch: &[Job],
    op: &'static str,
    (m, n): (usize, usize),
    mode: crate::config::DispatchMode,
    checksums: &[f64],
    acct: BatchAcct,
    queue_ms: &[f64],
    service_us: u64,
    marks: BatchMarks,
    chain_dims: Option<&[usize]>,
    dag: Option<(&DagShape, &[u64])>,
    metrics_prev: &mut Metrics,
) {
    let b = batch.len();
    let f = blas.engine.freq_hz();
    let ms = |cycles: u64| Cycles(cycles).to_ns(f) / 1e6 / b as f64;
    let dc = ms(acct.data_copy.saturating_sub(acct.hidden));
    let fj = ms(acct.fork_join);
    let cp = ms(acct.compute);
    let hc = ms(acct.host_compute);
    let total = dc + fj + cp + hc;

    // counters before replies: a submitter that observes its reply must
    // also observe the updated metrics
    counters.completed.fetch_add(b as u64, Ordering::Relaxed);
    counters.batches.fetch_add(1, Ordering::Relaxed);
    if let Some(pc) = counters.cluster(cluster) {
        pc.completed.fetch_add(b as u64, Ordering::Relaxed);
        pc.batches.fetch_add(1, Ordering::Relaxed);
    }
    if b > 1 {
        counters.batched_jobs.fetch_add(b as u64, Ordering::Relaxed);
    }
    if op == "chain" {
        counters.chains.fetch_add(b as u64, Ordering::Relaxed);
    }
    if let Some((shape, _)) = dag {
        counters.dags.fetch_add(b as u64, Ordering::Relaxed);
        counters
            .dag_nodes
            .fetch_add(shape.nodes.len() as u64, Ordering::Relaxed);
    }
    counters.note_service_us((service_us / b as u64).max(1));
    let metrics_now = blas.metrics();
    counters.absorb_engine_delta(cluster, metrics_prev, &metrics_now);
    *metrics_prev = metrics_now;

    // ---- calibration feedback: the batch's observed virtual time (the
    // trace deltas already measured above) folds back into the shared
    // cost model's EWMA scales, moving the estimated crossovers toward
    // what this platform actually does ----
    if let Some(model) = &blas.policy.model {
        if model.calibrate_enabled() {
            let device_total = acct.data_copy + acct.fork_join + acct.compute;
            if let Some((shape, node_cycles)) = dag {
                // per-link attribution: the executor measured each
                // node's own compute window, so the feedback lands on
                // the per-op family that actually ran it instead of
                // being smeared over the whole launch
                if device_total > 0 {
                    model.observe_dag_nodes(shape, node_cycles);
                }
                if acct.host_compute > 0 {
                    model.observe_dag_host(shape, acct.host_compute);
                }
            } else if let Some(cdims) = chain_dims {
                // chained launches have no single (m, n, k): fold the
                // observed virtual time back through the chain-cycle
                // predictors instead of silently skipping feedback
                if device_total > 0 {
                    model.observe_chain(m, cdims, device_total, false);
                }
                if acct.host_compute > 0 {
                    model.observe_chain(m, cdims, acct.host_compute, true);
                }
            } else {
                let dims = match op {
                    "gemm" => (m, n, n),
                    "gemv" => (m, n, 0),
                    _ => (n, 0, 0), // axpy/dot report (m, n) = (1, n)
                };
                if device_total > 0 {
                    model.observe(op, dims, b, device_total, false, acct.warm_b);
                    // a resident plan means the device walk took the
                    // specialized charge schedule — fold the observed
                    // timing into that kernel's own EWMA scale too, so
                    // the model learns per-kernel FPU rates
                    if let Some(reg) = &blas.policy.kernel {
                        if let Some(key) =
                            reg.key_for(op, "f64", dims, Epilogue::None)
                        {
                            if reg.has_plan(key) {
                                model
                                    .observe_kernel(key, op, dims, b, device_total);
                            }
                        }
                    }
                }
                if acct.host_compute > 0 {
                    model.observe(op, dims, b, acct.host_compute, true, false);
                }
            }
        }
    }

    // ---- kernel-registry launch feed: every completed member bumps
    // its (op, dtype, tile-shape) key — after `[kernel] promote_after`
    // of these, the next device staging compiles the specialized walk.
    // Host-served launches count too: a hot shape below the generic
    // crossover still earns its plan, and the dispatch policy's
    // specialized estimate can then move it onto the device. ----
    if let Some(reg) = &blas.policy.kernel {
        if reg.enabled() {
            let keys: Vec<u64> = if let Some((shape, _)) = dag {
                // DAG matmul nodes stage with their own epilogues, so
                // they earn (and later take) epilogue-fused plans
                let widths = shape.widths();
                shape
                    .nodes
                    .iter()
                    .enumerate()
                    .filter_map(|(i, nd)| {
                        if !nd.op.is_matmul() {
                            return None;
                        }
                        let k = shape.in_width(i);
                        let (kop, dims) = if nd.op == DagOp::Gemv {
                            ("gemv", (shape.m, k, 0))
                        } else {
                            ("gemm", (shape.m, widths[i], k))
                        };
                        reg.key_for(
                            kop,
                            "f64",
                            dims,
                            Epilogue::of(nd.bias, nd.relu),
                        )
                    })
                    .collect()
            } else {
                match chain_dims {
                    // chain links stage as plain gemms (m, w[0]) x
                    // (w[0], w[1]) with no per-link epilogue
                    Some(cdims) => cdims
                        .windows(2)
                        .filter_map(|w| {
                            reg.key_for(
                                "gemm",
                                "f64",
                                (m, w[1], w[0]),
                                Epilogue::None,
                            )
                        })
                        .collect(),
                    None => {
                        let dims = match op {
                            "gemm" => (m, n, n),
                            "gemv" => (m, n, 0),
                            _ => (n, 0, 0), // axpy/dot report (m, n) = (1, n)
                        };
                        reg.key_for(op, "f64", dims, Epilogue::None)
                            .into_iter()
                            .collect()
                    }
                }
            };
            for key in keys {
                for _ in 0..b {
                    reg.note_launch(key);
                }
            }
        }
    }

    inflight_sub(counters, cluster, b as u64);
    let end = Instant::now();
    // batch-phase windows for the flight recorder: collected instant,
    // then the staged (collect -> exec) and executed (exec -> done)
    // duration events, then the finished marker
    trace.span(
        cluster, EventKind::BatchCollected, marks.collected_at, 0, b as u64,
    );
    trace.span(
        cluster,
        EventKind::BatchStaged,
        marks.collected_at,
        dur_us(marks.collected_at, marks.exec_at),
        b as u64,
    );
    trace.span(
        cluster,
        EventKind::BatchExecuted,
        marks.exec_at,
        dur_us(marks.exec_at, marks.done_at),
        b as u64,
    );
    trace.span(
        cluster,
        EventKind::BatchFinished,
        marks.done_at,
        dur_us(marks.done_at, end),
        b as u64,
    );
    for ((job, checksum), wait) in batch.iter().zip(checksums).zip(queue_ms) {
        let mut spans =
            SpanBreakdown::compute(job.enqueued_at, job.spans, marks, end);
        // wall time lost to faulted attempts rides alongside the
        // telescoping stages, like the linger sub-span
        spans.retry_us = job.fault.retry_us;
        record_job_spans(trace, cluster, job, &spans, marks);
        counters.note_latency_us(op, cluster, spans.total_us);
        counters.note_span_us(
            spans.queue_us,
            spans.route_us,
            spans.linger_us,
            spans.stage_us,
            spans.execute_us,
            spans.finish_us,
        );
        if spans.retry_us > 0 {
            counters.note_retry_us(spans.retry_us);
        }
        let _ = job.reply.send(Ok(GemmOutcome {
            op,
            m,
            n,
            mode,
            checksum: *checksum,
            data_copy_ms: dc,
            fork_join_ms: fj,
            compute_ms: cp,
            host_compute_ms: hc,
            total_ms: total,
            cluster,
            batch_size: b,
            queue_ms: *wait,
            spans,
            degraded: false,
            attempts: job.fault.attempts,
        }));
    }
}

impl GemmOutcome {
    /// Ack for a fence job (no compute, no checksum).
    pub(crate) fn fence_ack(cluster: u32) -> GemmOutcome {
        GemmOutcome {
            op: "fence",
            m: 0,
            n: 0,
            mode: crate::config::DispatchMode::HostOnly,
            checksum: 0.0,
            data_copy_ms: 0.0,
            fork_join_ms: 0.0,
            compute_ms: 0.0,
            host_compute_ms: 0.0,
            total_ms: 0.0,
            cluster,
            batch_size: 1,
            queue_ms: 0.0,
            spans: SpanBreakdown::default(),
            degraded: false,
            attempts: 0,
        }
    }
}
