//! Pool workers: one thread per cluster, each owning a full offload
//! session.
//!
//! A worker boots its `HeroBlas` session *on its own thread* (engine,
//! PJRT registry and dispatch policy never cross threads), signals
//! readiness, then loops: pull a job, grow it into a batch (bounded by
//! the batcher policy AND by what the cluster's DRAM slice can stage),
//! consult the dispatch policy per batch, launch, poll the cluster
//! mailbox for the completion word, join, and reply to every member.
//! Requests complete asynchronously from the submitter's point of view —
//! the connection handler is parked on the reply channel, not on the
//! device.
//!
//! **Cancellation**: a job whose submitter stopped waiting (serve-layer
//! reply timeout sets its [`CancelToken`]) is skipped at dequeue — never
//! synthesized, staged or launched for a dropped receiver.
//!
//! **Software pipelining** (`[sched.cache] pipeline_depth >= 2`): the
//! gemm device path is split stage / execute / finish, and the worker
//! holds one executed-but-unfinished batch in flight.  When the next
//! batch arrives, its map-in is staged *before* the in-flight batch is
//! finished — i.e. during the window the in-flight batch's compute
//! occupies on a real device — so up to `min(map_in(k+1), compute(k))`
//! virtual cycles of data-copy are hidden.  The hidden share is
//! subtracted from the reported per-request times and accumulated in the
//! `overlap_hidden_us` counter; checksums are unaffected (the data path
//! is identical, only the attribution changes).  The cluster's DRAM
//! slice must hold two staged batches at once, so the per-batch capacity
//! cap is divided by the pipeline depth.
//!
//! Failures are contained per batch: the device error path releases the
//! staged mappings and aborts the launch, every member gets an error
//! reply, and the worker keeps serving.  A staging failure while a batch
//! is in flight first drains the pipeline (freeing its DRAM) and retries
//! once serially before giving up.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::blas::{DispatchPolicy, ExecTarget, GemmBatchRun, HeroBlas};
use crate::error::Result;
use crate::metrics::{Metrics, SchedCounters};
use crate::soc::clock::Cycles;
use crate::soc::trace::RegionClass;
use crate::util::rng::Rng;

use super::batcher::Batcher;
use super::pool::ClusterSpec;
use super::queue::WorkQueue;
use super::{GemmOutcome, GemmRequest, GemvRequest, Job, JobPayload};

/// Spawn one worker thread for `spec`.  It reports session boot success
/// or failure once through `ready`, then serves until the queue closes.
pub(crate) fn spawn(
    spec: ClusterSpec,
    artifacts: PathBuf,
    queue: Arc<WorkQueue>,
    counters: Arc<SchedCounters>,
    batcher: Batcher,
    ready: mpsc::Sender<Result<()>>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("sched-worker-{}", spec.id))
        .spawn(move || run(spec, artifacts, queue, counters, batcher, ready))
        .expect("spawn scheduler worker")
}

/// Per-batch virtual-time totals, in cycles (accumulated across the
/// stage / execute / finish phases from trace-region deltas, so two
/// interleaved pipeline batches never steal each other's time).
#[derive(Debug, Default, Clone, Copy)]
struct BatchAcct {
    data_copy: u64,
    fork_join: u64,
    compute: u64,
    host_compute: u64,
    /// Map-in cycles hidden under the previous batch's compute window
    /// (subtracted from `data_copy` and the total when reporting).
    hidden: u64,
}

impl BatchAcct {
    fn add(&mut self, other: BatchAcct) {
        self.data_copy += other.data_copy;
        self.fork_join += other.fork_join;
        self.compute += other.compute;
        self.host_compute += other.host_compute;
    }
}

/// Trace-region totals at a point in time.
#[derive(Debug, Clone, Copy)]
struct RegionSnap {
    dc: Cycles,
    fj: Cycles,
    cp: Cycles,
    hc: Cycles,
}

fn snap(blas: &HeroBlas) -> RegionSnap {
    let t = blas.trace();
    RegionSnap {
        dc: t.total(RegionClass::DataCopy),
        fj: t.total(RegionClass::ForkJoin),
        cp: t.total(RegionClass::Compute),
        hc: t.total(RegionClass::HostCompute),
    }
}

fn delta(before: RegionSnap, after: RegionSnap) -> BatchAcct {
    BatchAcct {
        data_copy: after.dc.saturating_sub(before.dc).0,
        fork_join: after.fj.saturating_sub(before.fj).0,
        compute: after.cp.saturating_sub(before.cp).0,
        host_compute: after.hc.saturating_sub(before.hc).0,
        hidden: 0,
    }
}

/// One coalesced gemm batch between its execute and its finish: the
/// completion word is posted in the cluster mailbox, results are still
/// on the device, replies are pending.
struct Inflight {
    jobs: Vec<Job>,
    req: GemmRequest,
    data: Vec<(Vec<f64>, Vec<f64>, Vec<f64>)>,
    run: GemmBatchRun<f64>,
    acct: BatchAcct,
    queue_ms: Vec<f64>,
    /// Wall microseconds this batch actively consumed through execute.
    /// The finish phase adds its own elapsed time — the idle gap while
    /// the batch sits in flight waiting for the next arrival must NOT
    /// count, or the service-time EWMA (and with it the retry-after
    /// backpressure hint) inflates under pipelining.
    work_us: u64,
}

fn run(
    spec: ClusterSpec,
    artifacts: PathBuf,
    queue: Arc<WorkQueue>,
    counters: Arc<SchedCounters>,
    batcher: Batcher,
    ready: mpsc::Sender<Result<()>>,
) {
    let mut blas = match boot_session(&spec, &artifacts) {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let _ = ready.send(Ok(()));

    // double-buffered staging: depth 2 is what the implementation holds
    let depth = (spec.cfg.sched.cache.pipeline_depth as usize).clamp(1, 2);
    let mut inflight: Option<Inflight> = None;
    let mut metrics_prev = blas.metrics();

    loop {
        // With a batch in flight never park: an empty queue means "drain
        // the pipeline now", not "sleep while a client waits".
        let next = if inflight.is_some() {
            queue.try_pop()
        } else {
            match queue.pop_blocking() {
                Some(j) => Some(j),
                None => break, // closed and drained; nothing in flight
            }
        };
        let Some(job) = next else {
            let infl = inflight.take().expect("try_pop only used with inflight");
            finish_batch(&mut blas, spec.id, &counters, infl, &mut metrics_prev);
            continue;
        };

        // Cancellation at dequeue: the submitter stopped waiting, so the
        // job is dropped before any synthesis or staging happens.
        if job.cancel.is_cancelled() {
            counters.cancelled.fetch_add(1, Ordering::Relaxed);
            continue;
        }

        match job.payload {
            JobPayload::Fence(ref release) => {
                // A fence drains the pipeline first: it is a barrier.
                if let Some(infl) = inflight.take() {
                    finish_batch(&mut blas, spec.id, &counters, infl, &mut metrics_prev);
                }
                // Park until the test/bench releases (or drops) the fence.
                let _ = release.recv();
                // counters first: a submitter that observes the reply must
                // also observe the updated metrics
                counters.completed.fetch_add(1, Ordering::Relaxed);
                let _ = job.reply.send(Ok(GemmOutcome::fence_ack(spec.id)));
            }
            JobPayload::Gemv(req) => {
                // level-2 batches run synchronously (they are small and
                // DMA-bound; pipelining them is not worth the state)
                if let Some(infl) = inflight.take() {
                    finish_batch(&mut blas, spec.id, &counters, infl, &mut metrics_prev);
                }
                serve_gemv_batch(
                    &mut blas, spec.id, &counters, &queue, &batcher, job, req,
                    &mut metrics_prev,
                );
            }
            JobPayload::Gemm(req) => {
                let cap = (gemm_batch_cap(&blas, req.n) / depth).max(1);
                let mut batch = batcher.collect(&queue, job, cap);
                drop_cancelled(&mut batch, &counters);
                if batch.is_empty() {
                    continue;
                }
                serve_gemm(
                    &mut blas,
                    spec.id,
                    &counters,
                    batch,
                    req,
                    depth,
                    &mut inflight,
                    &mut metrics_prev,
                );
            }
        }
    }

    // shutdown: drain whatever is still in flight before exiting
    if let Some(infl) = inflight.take() {
        finish_batch(&mut blas, spec.id, &counters, infl, &mut metrics_prev);
    }
}

fn boot_session(spec: &ClusterSpec, artifacts: &PathBuf) -> Result<HeroBlas> {
    let mut blas =
        HeroBlas::new(spec.cfg.clone(), artifacts, DispatchPolicy::default())?;
    blas.registry.warm_up()?; // no compile latency on the first request
    Ok(blas)
}

/// Remove members whose submitter cancelled while they were queued.
fn drop_cancelled(batch: &mut Vec<Job>, counters: &SchedCounters) {
    batch.retain(|j| {
        if j.cancel.is_cancelled() {
            counters.cancelled.fetch_add(1, Ordering::Relaxed);
            false
        } else {
            true
        }
    });
}

/// How many batch members this cluster's DRAM slice can stage at once,
/// with 2x headroom for alignment and the L2 descriptor staging.  The
/// pipelined worker divides this further by the pipeline depth, since
/// two batches' operands are resident at once.
fn gemm_batch_cap(blas: &HeroBlas, n: usize) -> usize {
    let per_member =
        crate::blas::device::gemm_staged_bytes::<f64>(&blas.registry, (n, n, n)).max(1);
    ((blas.engine.platform.cfg.memory.dev_dram_bytes / 2) / per_member).max(1) as usize
}

/// Same bound for a coalesced gemv batch.
fn gemv_batch_cap(blas: &HeroBlas, m: usize, n: usize) -> usize {
    let per_member =
        crate::blas::device::gemv_staged_bytes::<f64>(&blas.registry, (m, n)).max(1);
    ((blas.engine.platform.cfg.memory.dev_dram_bytes / 2) / per_member).max(1) as usize
}

/// Synthesize one gemm member's operands from its seeds: A continues the
/// request RNG stream; B either continues it (classic behavior) or comes
/// from its own `b_seed` stream, so same-`b_seed` requests share a
/// bit-identical B — the pattern the operand cache collapses into
/// refcount bumps.
fn synth_gemm(req: &GemmRequest, seed: u64, b_seed: Option<u64>)
              -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let n = req.n;
    let mut rng = Rng::new(seed);
    let a = rng.normal_vec(n * n);
    let b = match b_seed {
        None => rng.normal_vec(n * n),
        Some(s) => Rng::new(s).normal_vec(n * n),
    };
    (a, b, vec![0.0; n * n])
}

/// Wall-clock queue wait of every member, ms.
fn queue_waits(batch: &[Job]) -> Vec<f64> {
    batch
        .iter()
        .map(|j| j.enqueued_at.elapsed().as_secs_f64() * 1e3)
        .collect()
}

fn virt_us(blas: &HeroBlas, cycles: u64) -> u64 {
    (Cycles(cycles).to_ns(blas.engine.freq_hz()) / 1e3) as u64
}

/// Serve one coalesced gemm batch: host path and un-pipelined device
/// path complete inline; the pipelined device path leaves the batch in
/// flight (executed, completion word posted) for the next iteration to
/// overlap against.
#[allow(clippy::too_many_arguments)]
fn serve_gemm(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    batch: Vec<Job>,
    req: GemmRequest,
    depth: usize,
    inflight: &mut Option<Inflight>,
    metrics_prev: &mut Metrics,
) {
    let t0 = Instant::now();
    let n = req.n;
    blas.policy = DispatchPolicy::with_mode(req.mode);

    // ---- host path: no staging, no pipeline ----
    if blas.policy.gemm(n, n, n) == ExecTarget::Host {
        if let Some(infl) = inflight.take() {
            finish_batch(blas, cluster, counters, infl, metrics_prev);
        }
        serve_gemm_host(blas, cluster, counters, batch, req, t0, metrics_prev);
        return;
    }
    let zero_copy = blas.policy.gemm(n, n, n) == ExecTarget::DeviceZeroCopy;

    // ---- synthesize every member's operands from its seeds ----
    let data: Vec<(Vec<f64>, Vec<f64>, Vec<f64>)> = batch
        .iter()
        .map(|j| match &j.payload {
            JobPayload::Gemm(r) => synth_gemm(&req, r.seed, r.b_seed),
            _ => unreachable!("gemm batch contains only gemm jobs"),
        })
        .collect();
    let queue_ms = queue_waits(&batch);

    // ---- stage (map-in): this is the region pipelining hides ----
    if inflight.is_none() {
        blas.reset_run(); // bound trace growth between pipeline drains
    }
    let inputs: Vec<(&[f64], &[f64], &[f64])> = data
        .iter()
        .map(|(a, b, c)| (a.as_slice(), b.as_slice(), c.as_slice()))
        .collect();
    let mut before = snap(blas);
    let mut stage = blas.gemm_batch_stage((n, n, n), 1.0, 0.0, &inputs, zero_copy);
    if stage.is_err() && inflight.is_some() {
        // the in-flight batch's operands may be what keeps us from
        // fitting: drain the pipeline and retry once serially
        let infl = inflight.take().expect("checked above");
        finish_batch(blas, cluster, counters, infl, metrics_prev);
        before = snap(blas); // re-baseline: the failed attempt + drain
                             // must not bill this batch
        stage = blas.gemm_batch_stage((n, n, n), 1.0, 0.0, &inputs, zero_copy);
    }
    let staged_run = match stage {
        Ok(s) => s,
        Err(e) => {
            reply_error(counters, &batch, &e.to_string());
            return;
        }
    };
    drop(inputs);
    let stage_acct = delta(before, snap(blas));

    // ---- overlap credit, then drain the previous batch ----
    let mut hidden = 0u64;
    let mut pipelined = false;
    if let Some(infl) = inflight.take() {
        hidden = stage_acct.data_copy.min(infl.acct.compute);
        pipelined = true;
        finish_batch(blas, cluster, counters, infl, metrics_prev);
        // the drained batch is fully accounted and this batch's stage
        // delta is already materialized: safe to bound trace growth now
        // (everything after re-snapshots from the cleared trace)
        blas.reset_run();
    }

    // ---- execute (doorbell + compute; completion word posted) ----
    let before = snap(blas);
    let run = match blas.gemm_batch_execute(staged_run) {
        Ok(r) => r,
        Err(e) => {
            // the overlap credit is dropped with the batch: never report
            // hidden map-in for work that produced no results
            reply_error(counters, &batch, &e.to_string());
            return;
        }
    };
    if pipelined {
        counters.pipelined_batches.fetch_add(1, Ordering::Relaxed);
        counters
            .overlap_hidden_us
            .fetch_add(virt_us(blas, hidden), Ordering::Relaxed);
    }
    let mut acct = stage_acct;
    acct.add(delta(before, snap(blas)));
    acct.hidden = hidden;

    let infl = Inflight {
        jobs: batch,
        req,
        data,
        run,
        acct,
        queue_ms,
        work_us: t0.elapsed().as_micros() as u64,
    };
    if depth >= 2 {
        *inflight = Some(infl); // finished when the next job (or none) arrives
    } else {
        finish_batch(blas, cluster, counters, infl, metrics_prev);
    }
}

/// Error replies for every member of a failed batch, with the failure
/// counted once per member and the launch attempt counted as a batch.
fn reply_error(counters: &SchedCounters, batch: &[Job], msg: &str) {
    counters.failed.fetch_add(batch.len() as u64, Ordering::Relaxed);
    counters.batches.fetch_add(1, Ordering::Relaxed);
    for job in batch {
        let _ = job.reply.send(Err(msg.to_string()));
    }
}

/// Host-path gemm batch: one host kernel per member, no offload.
fn serve_gemm_host(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    batch: Vec<Job>,
    req: GemmRequest,
    t0: Instant,
    metrics_prev: &mut Metrics,
) {
    let n = req.n;
    let queue_ms = queue_waits(&batch);
    blas.reset_run();
    let before = snap(blas);
    let mut checksums = Vec::with_capacity(batch.len());
    for job in &batch {
        let JobPayload::Gemm(r) = &job.payload else {
            unreachable!("gemm batch contains only gemm jobs")
        };
        let (a, b, mut c) = synth_gemm(&req, r.seed, r.b_seed);
        let r = blas.gemm(
            crate::blas::Transpose::No,
            crate::blas::Transpose::No,
            1.0,
            &a,
            (n, n),
            &b,
            (n, n),
            0.0,
            &mut c,
            (n, n),
        );
        match r {
            Ok(()) => checksums.push(c.iter().sum::<f64>()),
            Err(e) => {
                reply_error(counters, &batch, &e.to_string());
                return;
            }
        }
    }
    let acct = delta(before, snap(blas));
    send_outcomes(
        blas, cluster, counters, &batch, "gemm", (n, n), req.mode, &checksums,
        acct, &queue_ms, t0.elapsed().as_micros() as u64, metrics_prev,
    );
}

/// Finish an executed batch: poll the mailbox completion word (posted at
/// execute time; the poll keeps the worker protocol-shaped for a backend
/// where compute genuinely overlaps the host), join, copy every member's
/// C back, release the mappings, and reply.
fn finish_batch(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    mut infl: Inflight,
    metrics_prev: &mut Metrics,
) {
    while !blas.offload_completion_pending() {
        std::thread::yield_now();
    }
    let t_finish = Instant::now();
    let before = snap(blas);
    let finish = {
        let mut outs: Vec<&mut [f64]> =
            infl.data.iter_mut().map(|(_, _, c)| c.as_mut_slice()).collect();
        blas.gemm_batch_finish(infl.run, &mut outs)
    };
    let mut acct = infl.acct;
    acct.add(delta(before, snap(blas)));

    match finish {
        Ok(()) => {
            let checksums: Vec<f64> =
                infl.data.iter().map(|(_, _, c)| c.iter().sum()).collect();
            let n = infl.req.n;
            // active wall time only: stage+execute plus this finish —
            // excluding the in-flight idle gap under pipelining
            let service_us = infl.work_us + t_finish.elapsed().as_micros() as u64;
            send_outcomes(
                blas,
                cluster,
                counters,
                &infl.jobs,
                "gemm",
                (n, n),
                infl.req.mode,
                &checksums,
                acct,
                &infl.queue_ms,
                service_us,
                metrics_prev,
            );
        }
        Err(e) => {
            reply_error(counters, &infl.jobs, &e.to_string());
        }
    }
}

/// Serve one coalesced gemv batch synchronously (host loop or one
/// fork-join device launch, decided by the dispatch policy).
#[allow(clippy::too_many_arguments)]
fn serve_gemv_batch(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    queue: &WorkQueue,
    batcher: &Batcher,
    first: Job,
    req: GemvRequest,
    metrics_prev: &mut Metrics,
) {
    let t0 = Instant::now();
    let (m, n) = (req.m, req.n);
    let cap = gemv_batch_cap(blas, m, n);
    let mut batch = batcher.collect(queue, first, cap);
    drop_cancelled(&mut batch, counters);
    if batch.is_empty() {
        return;
    }
    let queue_ms = queue_waits(&batch);

    // synthesize (A, x) per member; y starts at zero
    let data: Vec<(Vec<f64>, Vec<f64>)> = batch
        .iter()
        .map(|j| {
            let JobPayload::Gemv(r) = &j.payload else {
                unreachable!("gemv batch contains only gemv jobs")
            };
            let mut rng = Rng::new(r.seed);
            (rng.normal_vec(m * n), rng.normal_vec(n))
        })
        .collect();
    let mut ys: Vec<Vec<f64>> = vec![vec![0.0; m]; batch.len()];

    blas.policy = DispatchPolicy::with_mode(req.mode);
    blas.reset_run();
    let before = snap(blas);
    let result = {
        let a_refs: Vec<&[f64]> = data.iter().map(|(a, _)| a.as_slice()).collect();
        let x_refs: Vec<&[f64]> = data.iter().map(|(_, x)| x.as_slice()).collect();
        let mut outs: Vec<&mut [f64]> =
            ys.iter_mut().map(|y| y.as_mut_slice()).collect();
        blas.gemv_batch((m, n), 1.0, 0.0, &a_refs, &x_refs, &mut outs)
    };
    let acct = delta(before, snap(blas));

    match result {
        Ok(()) => {
            let checksums: Vec<f64> = ys.iter().map(|y| y.iter().sum()).collect();
            send_outcomes(
                blas, cluster, counters, &batch, "gemv", (m, n), req.mode,
                &checksums, acct, &queue_ms, t0.elapsed().as_micros() as u64,
                metrics_prev,
            );
        }
        Err(e) => {
            reply_error(counters, &batch, &e.to_string());
        }
    }
}

/// Counters + per-member outcome replies for one completed batch.
/// Uniform shapes => each member gets an even share of the batch's
/// virtual time; fork/join (and any pipelining credit) was accounted
/// once for all B.
#[allow(clippy::too_many_arguments)]
fn send_outcomes(
    blas: &mut HeroBlas,
    cluster: u32,
    counters: &SchedCounters,
    batch: &[Job],
    op: &'static str,
    (m, n): (usize, usize),
    mode: crate::config::DispatchMode,
    checksums: &[f64],
    acct: BatchAcct,
    queue_ms: &[f64],
    service_us: u64,
    metrics_prev: &mut Metrics,
) {
    let b = batch.len();
    let f = blas.engine.freq_hz();
    let ms = |cycles: u64| Cycles(cycles).to_ns(f) / 1e6 / b as f64;
    let dc = ms(acct.data_copy.saturating_sub(acct.hidden));
    let fj = ms(acct.fork_join);
    let cp = ms(acct.compute);
    let hc = ms(acct.host_compute);
    let total = dc + fj + cp + hc;

    // counters before replies: a submitter that observes its reply must
    // also observe the updated metrics
    counters.completed.fetch_add(b as u64, Ordering::Relaxed);
    counters.batches.fetch_add(1, Ordering::Relaxed);
    if b > 1 {
        counters.batched_jobs.fetch_add(b as u64, Ordering::Relaxed);
    }
    counters.note_service_us((service_us / b as u64).max(1));
    let metrics_now = blas.metrics();
    counters.absorb_engine_delta(metrics_prev, &metrics_now);
    *metrics_prev = metrics_now;

    for ((job, checksum), wait) in batch.iter().zip(checksums).zip(queue_ms) {
        let _ = job.reply.send(Ok(GemmOutcome {
            op,
            m,
            n,
            mode,
            checksum: *checksum,
            data_copy_ms: dc,
            fork_join_ms: fj,
            compute_ms: cp,
            host_compute_ms: hc,
            total_ms: total,
            cluster,
            batch_size: b,
            queue_ms: *wait,
        }));
    }
}

impl GemmOutcome {
    /// Ack for a fence job (no compute, no checksum).
    pub(crate) fn fence_ack(cluster: u32) -> GemmOutcome {
        GemmOutcome {
            op: "fence",
            m: 0,
            n: 0,
            mode: crate::config::DispatchMode::HostOnly,
            checksum: 0.0,
            data_copy_ms: 0.0,
            fork_join_ms: 0.0,
            compute_ms: 0.0,
            host_compute_ms: 0.0,
            total_ms: 0.0,
            cluster,
            batch_size: 1,
            queue_ms: 0.0,
        }
    }
}
