//! Always-on flight recorder: bounded, lock-free event rings.
//!
//! `span.rs` answers "where did THIS request's time go"; `metrics.rs`
//! answers "what are the aggregates".  Neither can answer "what
//! interleaving of jobs, batches, steals, evictions and faults caused
//! that p999 spike" — that needs an *event* record.  This module is
//! that record: one fixed-capacity ring per cluster (plus a global
//! track for pre-placement events), each slot a compact [`TraceEvent`]
//! stamped in microseconds on the same monotonic clock the span
//! machinery uses ([`std::time::Instant`]), so trace events reconcile
//! exactly with [`super::span::SpanBreakdown`] stages.
//!
//! Writers never block and never allocate: a writer claims a ticket
//! with one `fetch_add` on the ring's cursor, marks the target slot
//! in-progress, stores the payload, then publishes the ticket as the
//! slot's sequence number.  When the ring wraps, the oldest events are
//! overwritten — a flight recorder keeps the *recent* past, bounded by
//! `[sched.trace] ring_capacity`.  Readers ([`TraceRecorder::dump`])
//! validate each slot's sequence before and after copying it and skip
//! slots that moved underneath them, so a dump taken under load is a
//! consistent sample, never a torn record.
//!
//! The serve layer exposes the record three ways: `trace_dump` renders
//! [`chrome_trace_json`] (loadable in Perfetto / `chrome://tracing`),
//! `metrics_prom` renders the counter/histogram aggregates for
//! fleet-level scrape-and-merge, and `watch` streams the live `top`
//! view.  See `serve.rs`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::config::TraceConfig;

/// What happened.  Stored in the slot as a `u32` discriminant; the
/// groups mirror the serving path: job movement, batch lifecycle,
/// chain links, operand-cache traffic, placement churn, faults, and
/// the per-request span stages recorded at reply time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u32)]
pub enum EventKind {
    /// Job accepted into the ingress queue (`a` = job id, `b` = depth).
    JobEnqueued = 1,
    /// Router moved a job onto a cluster run queue (`a` = job id).
    JobRouted = 2,
    /// Worker claimed a job from its run queue (`a` = job id).
    JobClaimed = 3,
    /// Idle worker stole a job routed elsewhere (`a` = job id,
    /// `b` = victim cluster).
    JobStolen = 4,
    /// Batch assembly closed (`a` = launch seq, `b` = members).
    BatchCollected = 5,
    /// Operand staging done, fork-join issued (`a` = launch seq,
    /// `b` = staging duration in us).
    BatchStaged = 6,
    /// Device completion observed (`a` = launch seq, `b` = execute
    /// duration in us).
    BatchExecuted = 7,
    /// Copy-out + replies sent (`a` = launch seq, `b` = members).
    BatchFinished = 8,
    /// One chain link's device walk finished (`a` = job id,
    /// `b` = link index).
    ChainLink = 9,
    /// Operand cache hit (`a` = bytes).
    CacheHit = 10,
    /// Operand cache miss (`a` = bytes).
    CacheMiss = 11,
    /// Operand cache eviction (`a` = bytes).
    CacheEvict = 12,
    /// Fault recovery invalidated resident bytes (`a` = bytes).
    CacheInvalidate = 13,
    /// Directory-driven prefetch staged a cold operand (`a` = bytes).
    Prefetch = 14,
    /// Steal-fairness re-homed an operand key (`a` = key hash).
    Rehome = 15,
    /// Fault injected / detected (`a` = job or launch seq,
    /// `b` = seam code).
    FaultInjected = 16,
    /// Faulted job requeued for retry (`a` = job id, `b` = attempt).
    FaultRetry = 17,
    /// Cluster quarantined (`a` = fault count).
    Quarantine = 18,
    /// Quarantined cluster probed for re-admission (`a` = 1 if
    /// re-admitted).
    Probe = 19,
    /// Job degraded to the host BLAS path (`a` = job id,
    /// `b` = attempts).
    HostFallback = 20,
    /// Per-request span stages, recorded retrospectively at reply time
    /// from the same stamps `SpanBreakdown::compute` consumed — the
    /// event's start offset and duration (`b`, in us) equal the span
    /// stage exactly.  `a` = job id.
    SpanQueue = 21,
    SpanRoute = 22,
    SpanStage = 23,
    SpanExecute = 24,
    SpanFinish = 25,
    /// A hot (op, dtype, tile-shape) key crossed `[kernel]
    /// promote_after` and its specialized plan entered the registry
    /// (`a` = kernel key, `b` = launch count at promotion).
    KernelPromote = 26,
    /// A launch took a specialized fast-path walk (`a` = kernel key).
    KernelHit = 27,
    /// An incoming DAG spliced onto a just-completed DAG's still-pinned
    /// output instead of re-staging it (`a` = fuse key, `b` = elided
    /// input bytes).
    DagFuse = 28,
}

impl EventKind {
    fn from_u32(v: u32) -> Option<EventKind> {
        use EventKind::*;
        Some(match v {
            1 => JobEnqueued,
            2 => JobRouted,
            3 => JobClaimed,
            4 => JobStolen,
            5 => BatchCollected,
            6 => BatchStaged,
            7 => BatchExecuted,
            8 => BatchFinished,
            9 => ChainLink,
            10 => CacheHit,
            11 => CacheMiss,
            12 => CacheEvict,
            13 => CacheInvalidate,
            14 => Prefetch,
            15 => Rehome,
            16 => FaultInjected,
            17 => FaultRetry,
            18 => Quarantine,
            19 => Probe,
            20 => HostFallback,
            21 => SpanQueue,
            22 => SpanRoute,
            23 => SpanStage,
            24 => SpanExecute,
            25 => SpanFinish,
            26 => KernelPromote,
            27 => KernelHit,
            28 => DagFuse,
            _ => return None,
        })
    }

    /// Chrome-trace event name.  Span stages use the bare stage names
    /// so a Perfetto track reads like the `SpanBreakdown` it mirrors.
    pub fn label(self) -> &'static str {
        use EventKind::*;
        match self {
            JobEnqueued => "job-enqueued",
            JobRouted => "job-routed",
            JobClaimed => "job-claimed",
            JobStolen => "job-stolen",
            BatchCollected => "batch-collected",
            BatchStaged => "batch-staged",
            BatchExecuted => "batch-executed",
            BatchFinished => "batch-finished",
            ChainLink => "chain-link",
            CacheHit => "cache-hit",
            CacheMiss => "cache-miss",
            CacheEvict => "cache-evict",
            CacheInvalidate => "cache-invalidate",
            Prefetch => "prefetch",
            Rehome => "rehome",
            FaultInjected => "fault-injected",
            FaultRetry => "fault-retry",
            Quarantine => "quarantine",
            Probe => "probe",
            HostFallback => "host-fallback",
            SpanQueue => "queue",
            SpanRoute => "route",
            SpanStage => "stage",
            SpanExecute => "execute",
            SpanFinish => "finish",
            KernelPromote => "kernel-promote",
            KernelHit => "kernel-hit",
            DagFuse => "dag-fuse",
        }
    }

    /// Duration events render as Chrome `ph: "X"` slices with `b` as
    /// the duration; everything else is a `ph: "i"` instant.
    pub fn is_duration(self) -> bool {
        use EventKind::*;
        matches!(
            self,
            BatchStaged
                | BatchExecuted
                | SpanQueue
                | SpanRoute
                | SpanStage
                | SpanExecute
                | SpanFinish
        )
    }
}

/// One decoded flight-recorder event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Global-per-ring monotone sequence (1-based ticket).
    pub seq: u64,
    /// Microseconds since the recorder's epoch — the same `Instant`
    /// clock the span machinery stamps, offset to one shared origin.
    pub t_us: u64,
    pub kind: EventKind,
    /// Owning cluster, or [`GLOBAL_TRACK`] for pre-placement events.
    pub cluster: u32,
    /// Kind-specific payload (job id, launch seq, bytes, ...).
    pub a: u64,
    /// Kind-specific payload; the duration in us for duration kinds.
    pub b: u64,
}

/// Cluster id used for events not owned by any cluster (ingress).
pub const GLOBAL_TRACK: u32 = u32::MAX;

/// Slot sequence sentinel: a writer is mid-store.
const IN_PROGRESS: u64 = u64::MAX;

/// One ring slot.  Five relaxed atomics bracketed by the `seq`
/// store-release pair; no locks, no unsafe, no allocation after boot.
#[derive(Debug)]
struct Slot {
    /// 0 = never written, [`IN_PROGRESS`] = being written, else the
    /// 1-based ticket of the event currently stored here.
    seq: AtomicU64,
    t_us: AtomicU64,
    /// `kind << 32 | cluster`.
    kc: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            t_us: AtomicU64::new(0),
            kc: AtomicU64::new(0),
            a: AtomicU64::new(0),
            b: AtomicU64::new(0),
        }
    }
}

/// A fixed-capacity overwrite-oldest event ring.
#[derive(Debug)]
struct EventRing {
    /// Next ticket; `ticket % capacity` is the target slot.
    cursor: AtomicU64,
    slots: Vec<Slot>,
}

impl EventRing {
    fn new(capacity: usize) -> EventRing {
        EventRing {
            cursor: AtomicU64::new(0),
            slots: (0..capacity).map(|_| Slot::new()).collect(),
        }
    }

    fn record(&self, t_us: u64, kind: EventKind, cluster: u32, a: u64, b: u64) {
        let ticket = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket % self.slots.len() as u64) as usize];
        // Mark in-progress so a concurrent reader skips the slot, then
        // publish the ticket with release ordering so a reader that
        // observes it also observes the payload stores.
        slot.seq.store(IN_PROGRESS, Ordering::Release);
        slot.t_us.store(t_us, Ordering::Relaxed);
        slot.kc
            .store((kind as u64) << 32 | cluster as u64, Ordering::Relaxed);
        slot.a.store(a, Ordering::Relaxed);
        slot.b.store(b, Ordering::Relaxed);
        slot.seq.store(ticket + 1, Ordering::Release);
    }

    /// Snapshot every valid slot.  A slot whose sequence changes while
    /// we copy it was overwritten mid-read and is skipped — under a
    /// wrapping writer the dump loses that one slot, never tears it.
    fn dump(&self, out: &mut Vec<TraceEvent>) {
        for slot in &self.slots {
            let seq = slot.seq.load(Ordering::Acquire);
            if seq == 0 || seq == IN_PROGRESS {
                continue;
            }
            let t_us = slot.t_us.load(Ordering::Relaxed);
            let kc = slot.kc.load(Ordering::Relaxed);
            let a = slot.a.load(Ordering::Relaxed);
            let b = slot.b.load(Ordering::Relaxed);
            if slot.seq.load(Ordering::Acquire) != seq {
                continue;
            }
            let Some(kind) = EventKind::from_u32((kc >> 32) as u32) else {
                continue;
            };
            out.push(TraceEvent {
                seq,
                t_us,
                kind,
                cluster: kc as u32,
                a,
                b,
            });
        }
    }

    /// Total events ever recorded (not the retained count).
    fn recorded(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }
}

/// The pool-wide flight recorder: one ring per cluster plus a global
/// ingress track, all stamped against one epoch `Instant`.
#[derive(Debug)]
pub struct TraceRecorder {
    enabled: bool,
    epoch: Instant,
    /// `rings[0]` is the global track; `rings[1 + c]` is cluster `c`.
    rings: Vec<EventRing>,
}

impl TraceRecorder {
    pub fn new(cfg: &TraceConfig, clusters: u32) -> Arc<TraceRecorder> {
        let cap = if cfg.enabled {
            (cfg.ring_capacity as usize).max(1)
        } else {
            // disabled recorders keep one-slot rings so every record
            // path stays branch-cheap without allocating real capacity
            1
        };
        Arc::new(TraceRecorder {
            enabled: cfg.enabled,
            epoch: Instant::now(),
            rings: (0..=clusters as usize).map(|_| EventRing::new(cap)).collect(),
        })
    }

    /// A recorder that never records — for tests and synthetic boots.
    pub fn disabled() -> Arc<TraceRecorder> {
        TraceRecorder::new(
            &TraceConfig { enabled: false, ..TraceConfig::default() },
            0,
        )
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Microseconds since the recorder epoch for an arbitrary stamp on
    /// the span clock.  Stamps taken before boot collapse to 0.
    pub fn offset_us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    fn ring(&self, cluster: u32) -> &EventRing {
        let idx = if cluster == GLOBAL_TRACK {
            0
        } else {
            (cluster as usize + 1).min(self.rings.len() - 1)
        };
        &self.rings[idx]
    }

    /// Record an instant event stamped "now".  `cluster` selects the
    /// ring ([`GLOBAL_TRACK`] for pre-placement events).
    pub fn instant(&self, cluster: u32, kind: EventKind, a: u64, b: u64) {
        if !self.enabled {
            return;
        }
        let t_us = self.offset_us(Instant::now());
        self.ring(cluster).record(t_us, kind, cluster, a, b);
    }

    /// Record a duration event whose start is an existing span-clock
    /// stamp and whose duration is already known (the retrospective
    /// span/batch-stage path): the stored offset and `dur_us` come
    /// straight from the same values `SpanBreakdown` reports, so trace
    /// and spans reconcile exactly.
    pub fn span(
        &self,
        cluster: u32,
        kind: EventKind,
        start: Instant,
        dur_us: u64,
        a: u64,
    ) {
        if !self.enabled {
            return;
        }
        let t_us = self.offset_us(start);
        self.ring(cluster).record(t_us, kind, cluster, a, dur_us);
    }

    /// Decode every retained event across all rings, oldest first
    /// (by timestamp, then ring sequence).
    pub fn dump(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for ring in &self.rings {
            ring.dump(&mut out);
        }
        out.sort_by_key(|e| (e.t_us, e.seq));
        out
    }

    /// Total events recorded since boot (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        self.rings.iter().map(EventRing::recorded).sum()
    }

    /// Events currently retained across the rings.
    pub fn retained(&self) -> usize {
        let mut out = Vec::new();
        for ring in &self.rings {
            ring.dump(&mut out);
        }
        out.len()
    }

    /// Render the retained events as Chrome trace-event JSON.
    pub fn chrome_json(&self) -> String {
        chrome_trace_json(&self.dump())
    }
}

/// Chrome trace-event JSON (the `chrome://tracing` / Perfetto format):
/// one `ph: "X"` complete event per duration kind (span stages, batch
/// stage/execute windows) and one `ph: "i"` instant per everything
/// else, with `tid` = cluster track (0 = the global ingress track).
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    let mut out = String::with_capacity(64 + events.len() * 96);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let tid = if e.cluster == GLOBAL_TRACK {
            0
        } else {
            e.cluster as u64 + 1
        };
        if e.kind.is_duration() {
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                 \"pid\":1,\"tid\":{},\"args\":{{\"a\":{},\"seq\":{}}}}}",
                e.kind.label(),
                e.t_us,
                e.b,
                tid,
                e.a,
                e.seq
            ));
        } else {
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{},\
                 \"pid\":1,\"tid\":{},\"args\":{{\"a\":{},\"b\":{},\"seq\":{}}}}}",
                e.kind.label(),
                e.t_us,
                tid,
                e.a,
                e.b,
                e.seq
            ));
        }
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json_lite::Json;
    use std::time::Duration;

    fn recorder(cap: u64, clusters: u32) -> Arc<TraceRecorder> {
        TraceRecorder::new(
            &TraceConfig {
                enabled: true,
                ring_capacity: cap,
                ..TraceConfig::default()
            },
            clusters,
        )
    }

    #[test]
    fn records_and_dumps_in_time_order() {
        let r = recorder(16, 2);
        r.instant(GLOBAL_TRACK, EventKind::JobEnqueued, 7, 1);
        r.instant(0, EventKind::JobClaimed, 7, 0);
        r.instant(1, EventKind::CacheHit, 4096, 0);
        let events = r.dump();
        assert_eq!(events.len(), 3);
        assert!(events.windows(2).all(|w| w[0].t_us <= w[1].t_us));
        assert_eq!(events.iter().filter(|e| e.kind == EventKind::CacheHit).count(), 1);
        assert_eq!(r.recorded(), 3);
    }

    #[test]
    fn ring_overwrites_oldest_when_full() {
        let r = recorder(4, 0);
        for i in 0..10u64 {
            r.instant(0, EventKind::JobClaimed, i, 0);
        }
        let events = r.dump();
        assert_eq!(events.len(), 4, "capacity bounds retention");
        let ids: Vec<u64> = events.iter().map(|e| e.a).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![6, 7, 8, 9], "the newest events survive");
        assert_eq!(r.recorded(), 10, "recorded counts overwritten events");
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let r = TraceRecorder::disabled();
        assert!(!r.enabled());
        r.instant(0, EventKind::JobClaimed, 1, 0);
        r.span(0, EventKind::SpanQueue, Instant::now(), 10, 1);
        assert!(r.dump().is_empty());
        assert_eq!(r.recorded(), 0);
    }

    #[test]
    fn span_events_carry_exact_offsets_and_durations() {
        let r = recorder(16, 1);
        let start = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        r.span(0, EventKind::SpanExecute, start, 1234, 42);
        let events = r.dump();
        assert_eq!(events.len(), 1);
        let e = events[0];
        assert_eq!(e.kind, EventKind::SpanExecute);
        assert_eq!(e.b, 1234, "duration is stored verbatim");
        assert_eq!(e.a, 42);
        assert_eq!(e.t_us, r.offset_us(start), "start offset is the span stamp");
    }

    #[test]
    fn pre_epoch_stamps_saturate_to_zero() {
        let early = Instant::now();
        std::thread::sleep(Duration::from_millis(1));
        let r = recorder(4, 0);
        assert_eq!(r.offset_us(early), 0);
    }

    #[test]
    fn concurrent_writers_never_tear_a_dump() {
        let r = recorder(64, 3);
        let mut handles = Vec::new();
        for c in 0..3u32 {
            let rc = Arc::clone(&r);
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    rc.instant(c, EventKind::CacheMiss, i, c as u64);
                }
            }));
        }
        // dump concurrently with the writers: every decoded event must
        // be internally consistent (payload b echoes the writer's track)
        for _ in 0..20 {
            for e in r.dump() {
                assert_eq!(e.b, e.cluster as u64, "torn slot leaked out");
                assert!(e.a < 500);
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.recorded(), 1500);
        assert_eq!(r.retained(), 3 * 64, "each cluster ring is full");
    }

    #[test]
    fn chrome_json_is_valid_and_typed() {
        let r = recorder(16, 1);
        r.instant(GLOBAL_TRACK, EventKind::FaultInjected, 9, 1);
        r.span(0, EventKind::SpanStage, Instant::now(), 55, 9);
        let json = r.chrome_json();
        let v = Json::parse(&json).expect("chrome trace must parse");
        let events = v.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(events.len(), 2);
        let phs: Vec<&str> = events
            .iter()
            .map(|e| e.get("ph").and_then(|p| p.as_str()).unwrap())
            .collect();
        assert!(phs.contains(&"X"), "duration events render as ph X");
        assert!(phs.contains(&"i"), "instants render as ph i");
        for e in events {
            assert!(e.get("ts").and_then(|t| t.as_u64()).is_some());
            assert!(e.get("tid").and_then(|t| t.as_u64()).is_some());
        }
        // the X event carries the exact duration
        let x = events
            .iter()
            .find(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .unwrap();
        assert_eq!(x.get("dur").and_then(|d| d.as_u64()), Some(55));
        assert_eq!(x.get("name").and_then(|n| n.as_str()), Some("stage"));
    }

    #[test]
    fn empty_recorder_renders_an_empty_valid_trace() {
        let r = recorder(4, 0);
        let v = Json::parse(&r.chrome_json()).unwrap();
        assert_eq!(
            v.get("traceEvents").and_then(|e| e.as_arr()).map(<[Json]>::len),
            Some(0)
        );
    }
}
