//! Device-memory arenas — the analogue of `hero_allocator.c`.
//!
//! Two arenas exist on the paper's platform: the dual-port L2 SPM
//! (device instructions + constants) and the device-managed DRAM
//! partition ("manually managed to avoid fragmentation", so shared
//! buffers stay physically contiguous for the DMA).  This is a first-fit
//! free-list allocator with coalescing; the DRAM arena carries a real
//! byte backing store so copies in the offload path move actual data —
//! functional correctness rides on it.



use crate::error::{Error, Result};

/// One live allocation (offset is arena-relative; `addr` device-visible).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Allocation {
    pub offset: u64,
    pub len: u64,
    pub addr: u64,
}

/// Allocator statistics.
#[derive(Debug, Default, Clone, Copy)]
pub struct ArenaStats {
    pub allocs: u64,
    pub frees: u64,
    pub bytes_in_use: u64,
    pub peak_bytes_in_use: u64,
    pub failed_allocs: u64,
}

/// First-fit arena with optional byte backing.
#[derive(Debug)]
pub struct Arena {
    name: &'static str,
    base: u64,
    size: u64,
    align: u64,
    /// Sorted, disjoint free holes (offset, len).
    free: Vec<(u64, u64)>,
    /// Live allocations (offset, len) for double-free/overlap checks.
    live: Vec<(u64, u64)>,
    /// Byte backing store (DRAM arena only).
    backing: Option<Vec<u8>>,
    stats: ArenaStats,
}

impl Arena {
    /// Bookkeeping-only arena (L2 SPM).
    pub fn new(name: &'static str, base: u64, size: u64, align: u64) -> Self {
        assert!(align.is_power_of_two(), "alignment must be a power of two");
        Arena {
            name,
            base,
            size,
            align,
            free: vec![(0, size)],
            live: Vec::new(),
            backing: None,
            stats: ArenaStats::default(),
        }
    }

    /// Arena with a real byte store (device DRAM partition).
    pub fn with_backing(name: &'static str, base: u64, size: u64, align: u64) -> Self {
        let mut a = Arena::new(name, base, size, align);
        a.backing = Some(vec![0u8; size as usize]);
        a
    }

    pub fn size(&self) -> u64 {
        self.size
    }

    pub fn base(&self) -> u64 {
        self.base
    }

    fn round_up(&self, v: u64) -> u64 {
        (v + self.align - 1) & !(self.align - 1)
    }

    /// First-fit allocation.
    pub fn alloc(&mut self, len: u64) -> Result<Allocation> {
        if len == 0 {
            return Err(Error::Alloc(format!("{}: zero-length alloc", self.name)));
        }
        let len = self.round_up(len);
        for i in 0..self.free.len() {
            let (off, hole) = self.free[i];
            if hole >= len {
                if hole == len {
                    self.free.remove(i);
                } else {
                    self.free[i] = (off + len, hole - len);
                }
                self.live.push((off, len));
                self.stats.allocs += 1;
                self.stats.bytes_in_use += len;
                self.stats.peak_bytes_in_use =
                    self.stats.peak_bytes_in_use.max(self.stats.bytes_in_use);
                return Ok(Allocation { offset: off, len, addr: self.base + off });
            }
        }
        self.stats.failed_allocs += 1;
        Err(Error::Alloc(format!(
            "{}: out of memory allocating {} B ({} B free, largest hole {} B)",
            self.name,
            len,
            self.free.iter().map(|(_, l)| l).sum::<u64>(),
            self.free.iter().map(|(_, l)| *l).max().unwrap_or(0),
        )))
    }

    /// Free and coalesce.
    pub fn free(&mut self, a: Allocation) -> Result<()> {
        let pos = self
            .live
            .iter()
            .position(|&(off, len)| off == a.offset && len == a.len)
            .ok_or_else(|| {
                Error::Alloc(format!(
                    "{}: free of unknown allocation at offset {}",
                    self.name, a.offset
                ))
            })?;
        self.live.remove(pos);
        self.stats.frees += 1;
        self.stats.bytes_in_use -= a.len;

        // insert hole sorted, then coalesce neighbours
        let idx = self.free.partition_point(|&(off, _)| off < a.offset);
        self.free.insert(idx, (a.offset, a.len));
        self.coalesce();
        Ok(())
    }

    fn coalesce(&mut self) {
        let mut i = 0;
        while i + 1 < self.free.len() {
            let (off, len) = self.free[i];
            let (noff, nlen) = self.free[i + 1];
            if off + len == noff {
                self.free[i] = (off, len + nlen);
                self.free.remove(i + 1);
            } else {
                i += 1;
            }
        }
    }

    /// Copy host bytes into the arena's backing store at an allocation.
    pub fn write(&mut self, a: &Allocation, data: &[u8]) -> Result<()> {
        if data.len() as u64 > a.len {
            return Err(Error::Alloc(format!(
                "{}: write of {} B into {} B allocation",
                self.name,
                data.len(),
                a.len
            )));
        }
        let store = self.backing.as_mut().ok_or_else(|| {
            Error::Alloc(format!("{}: arena has no backing store", self.name))
        })?;
        let s = a.offset as usize;
        store[s..s + data.len()].copy_from_slice(data);
        Ok(())
    }

    /// Zero an allocation's backing bytes (`map(alloc:)` staging: the
    /// device gets a defined-content buffer without a host copy, so the
    /// engine charges no data-copy time for it).  The arena recycles
    /// offsets, so stale bytes from a freed neighbour must never leak
    /// into a fresh output buffer.
    pub fn write_zeroes(&mut self, a: &Allocation) -> Result<()> {
        let store = self.backing.as_mut().ok_or_else(|| {
            Error::Alloc(format!("{}: arena has no backing store", self.name))
        })?;
        let s = a.offset as usize;
        store[s..s + a.len as usize].fill(0);
        Ok(())
    }

    /// Write bytes at an offset within an allocation.
    pub fn write_at(&mut self, a: &Allocation, offset: usize, data: &[u8]) -> Result<()> {
        if (offset + data.len()) as u64 > a.len {
            return Err(Error::Alloc(format!(
                "{}: write_at past end ({} + {} > {})",
                self.name,
                offset,
                data.len(),
                a.len
            )));
        }
        let store = self.backing.as_mut().ok_or_else(|| {
            Error::Alloc(format!("{}: arena has no backing store", self.name))
        })?;
        let s = a.offset as usize + offset;
        store[s..s + data.len()].copy_from_slice(data);
        Ok(())
    }

    /// Read bytes at an offset within an allocation.
    pub fn read_at(&self, a: &Allocation, offset: usize, len: usize) -> Result<&[u8]> {
        if (offset + len) as u64 > a.len {
            return Err(Error::Alloc(format!(
                "{}: read_at past end ({offset} + {len} > {})",
                self.name, a.len
            )));
        }
        let store = self.backing.as_ref().ok_or_else(|| {
            Error::Alloc(format!("{}: arena has no backing store", self.name))
        })?;
        let s = a.offset as usize + offset;
        Ok(&store[s..s + len])
    }

    /// Read bytes back from the backing store.
    pub fn read(&self, a: &Allocation, len: usize) -> Result<&[u8]> {
        if len as u64 > a.len {
            return Err(Error::Alloc(format!(
                "{}: read of {len} B from {} B allocation",
                self.name, a.len
            )));
        }
        let store = self.backing.as_ref().ok_or_else(|| {
            Error::Alloc(format!("{}: arena has no backing store", self.name))
        })?;
        let s = a.offset as usize;
        Ok(&store[s..s + len])
    }

    pub fn stats(&self) -> ArenaStats {
        self.stats
    }

    /// Free bytes remaining.
    pub fn free_bytes(&self) -> u64 {
        self.free.iter().map(|(_, l)| l).sum()
    }

    /// External fragmentation: 1 - largest_hole / free_bytes (0 when
    /// empty or fully coalesced).
    pub fn fragmentation(&self) -> f64 {
        let free = self.free_bytes();
        if free == 0 {
            return 0.0;
        }
        let largest = self.free.iter().map(|(_, l)| *l).max().unwrap_or(0);
        1.0 - largest as f64 / free as f64
    }

    /// Invariant check used by proptests: holes sorted/disjoint, live and
    /// free account for the whole arena, no live overlap.
    pub fn check_invariants(&self) -> Result<()> {
        let mut prev_end = 0u64;
        for &(off, len) in &self.free {
            if off < prev_end {
                return Err(Error::Alloc("free list unsorted/overlapping".into()));
            }
            prev_end = off + len;
        }
        let mut all: Vec<(u64, u64)> = self.free.iter().chain(self.live.iter()).copied().collect();
        all.sort_unstable();
        let mut covered = 0u64;
        let mut cursor = 0u64;
        for (off, len) in all {
            if off < cursor {
                return Err(Error::Alloc("live/free regions overlap".into()));
            }
            cursor = off + len;
            covered += len;
        }
        if covered != self.size {
            return Err(Error::Alloc(format!(
                "accounting leak: covered {covered} of {} B",
                self.size
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena() -> Arena {
        Arena::new("test", 0x1000, 4096, 64)
    }

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = arena();
        let x = a.alloc(100).unwrap();
        assert_eq!(x.len, 128); // rounded to alignment
        assert_eq!(x.addr, 0x1000);
        a.check_invariants().unwrap();
        a.free(x).unwrap();
        assert_eq!(a.free_bytes(), 4096);
        a.check_invariants().unwrap();
    }

    #[test]
    fn oom_reports_and_counts() {
        let mut a = arena();
        assert!(a.alloc(4096).is_ok());
        let e = a.alloc(1).unwrap_err();
        assert!(e.to_string().contains("out of memory"));
        assert_eq!(a.stats().failed_allocs, 1);
    }

    #[test]
    fn double_free_rejected() {
        let mut a = arena();
        let x = a.alloc(64).unwrap();
        a.free(x).unwrap();
        assert!(a.free(x).is_err());
    }

    #[test]
    fn coalescing_recovers_full_block() {
        let mut a = arena();
        let x = a.alloc(1024).unwrap();
        let y = a.alloc(1024).unwrap();
        let z = a.alloc(1024).unwrap();
        a.free(y).unwrap(); // hole in the middle
        assert!(a.fragmentation() > 0.0);
        a.free(x).unwrap();
        a.free(z).unwrap();
        assert_eq!(a.free_bytes(), 4096);
        assert_eq!(a.fragmentation(), 0.0);
        assert!(a.alloc(4096).is_ok());
    }

    #[test]
    fn backing_write_read() {
        let mut a = Arena::with_backing("dram", 0xA000_0000, 4096, 64);
        let x = a.alloc(256).unwrap();
        let data: Vec<u8> = (0..=255).collect();
        a.write(&x, &data).unwrap();
        assert_eq!(a.read(&x, 256).unwrap(), &data[..]);
        // oversized write rejected
        assert!(a.write(&x, &vec![0; 300]).is_err());
    }

    #[test]
    fn bookkeeping_arena_rejects_io() {
        let mut a = arena();
        let x = a.alloc(64).unwrap();
        assert!(a.write(&x, &[1, 2]).is_err());
        assert!(a.read(&x, 2).is_err());
        assert!(a.write_zeroes(&x).is_err());
    }

    #[test]
    fn write_zeroes_clears_recycled_bytes() {
        let mut a = Arena::with_backing("dram", 0xA000_0000, 4096, 64);
        let x = a.alloc(128).unwrap();
        a.write(&x, &[0xAB; 128]).unwrap();
        a.free(x).unwrap();
        // the recycled offset still holds stale bytes until zeroed
        let y = a.alloc(128).unwrap();
        assert_eq!(y.offset, x.offset);
        a.write_zeroes(&y).unwrap();
        assert_eq!(a.read(&y, 128).unwrap(), &[0u8; 128][..]);
    }

    #[test]
    fn peak_usage_tracked() {
        let mut a = arena();
        let x = a.alloc(2048).unwrap();
        let y = a.alloc(1024).unwrap();
        a.free(x).unwrap();
        a.free(y).unwrap();
        assert_eq!(a.stats().peak_bytes_in_use, 3072);
        assert_eq!(a.stats().bytes_in_use, 0);
    }

    #[test]
    fn zero_len_rejected() {
        let mut a = arena();
        assert!(a.alloc(0).is_err());
    }
}
