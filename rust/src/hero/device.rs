//! Device lifecycle — the analogue of `hero_snitch.c`.
//!
//! Boot copies the device-side functions of `libopenblas.so` into the
//! dual-port L2 SPM and wakes the cluster; launch posts an offload
//! descriptor through the mailbox; wait drains the completion word.
//! Costs are returned as cycles and charged by the offload engine.

use super::allocator::{Allocation, Arena};
use super::offload::OffloadDescriptor;
use crate::config::PlatformConfig;
use crate::error::{Error, Result};
use crate::soc::clock::Cycles;
use crate::soc::mailbox::Mailbox;

/// Device lifecycle state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceState {
    /// Held in reset; no arenas initialized.
    Reset,
    /// Booted: device binary resident in L2, cluster idle (clock-gated).
    Idle,
    /// One offload in flight.
    Running,
}

/// The PMCA as the Hero runtime sees it.
#[derive(Debug)]
pub struct Device {
    state: DeviceState,
    /// Dual-port L2 SPM: device .text/.rodata + descriptor staging.
    pub l2: Arena,
    /// Device-managed DRAM partition (physically contiguous, backed).
    pub dram: Arena,
    pub mailbox: Mailbox,
    binary: Option<Allocation>,
    launches: u64,
    wakeup_cycles: u64,
}

impl Device {
    pub fn new(cfg: &PlatformConfig) -> Self {
        let m = &cfg.memory;
        Device {
            state: DeviceState::Reset,
            l2: Arena::new("l2_spm", m.l2_spm_base, m.l2_spm_bytes, 64),
            dram: Arena::with_backing("dev_dram", m.dev_dram_base, m.dev_dram_bytes, 64),
            mailbox: Mailbox::new(cfg.forkjoin.doorbell_cycles),
            binary: None,
            launches: 0,
            wakeup_cycles: cfg.forkjoin.device_wakeup_cycles,
        }
    }

    pub fn state(&self) -> DeviceState {
        self.state
    }

    pub fn launches(&self) -> u64 {
        self.launches
    }

    /// Boot: stage the device binary (the `libopenblas.so` device
    /// sections) into L2 and release the cluster from reset.  Returns the
    /// boot cost; only valid from `Reset`.
    pub fn boot(&mut self, binary_bytes: u64, copy_cost: Cycles) -> Result<Cycles> {
        if self.state != DeviceState::Reset {
            return Err(Error::Device(format!(
                "boot from {:?} (must be Reset)",
                self.state
            )));
        }
        let alloc = self.l2.alloc(binary_bytes)?;
        self.binary = Some(alloc);
        self.state = DeviceState::Idle;
        // copy of the binary + wake-up out of reset
        Ok(copy_cost + Cycles(self.wakeup_cycles))
    }

    /// Post an offload descriptor; returns the doorbell+wake cost.
    pub fn launch(&mut self, desc: &OffloadDescriptor) -> Result<Cycles> {
        if self.state != DeviceState::Idle {
            return Err(Error::Device(format!(
                "launch from {:?} (must be Idle — boot first, one offload at a time)",
                self.state
            )));
        }
        // stage the descriptor in L2 (tiny, but it must fit)
        let staged = self.l2.alloc(64 + 24 * desc.args.len().max(1) as u64)?;
        let doorbell = self.mailbox.ring_device(staged.addr);
        self.l2.free(staged)?;
        self.state = DeviceState::Running;
        self.launches += 1;
        // cluster wakes from clock-gated idle on the doorbell IRQ
        Ok(doorbell + Cycles(self.wakeup_cycles))
    }

    /// Device signals completion (called by the compute engine when the
    /// kernel finishes); host-side `wait` then observes it.
    pub fn complete(&mut self) -> Result<Cycles> {
        if self.state != DeviceState::Running {
            return Err(Error::Device(format!(
                "complete from {:?} (no offload in flight)",
                self.state
            )));
        }
        let c = self.mailbox.ring_host(1);
        self.state = DeviceState::Idle;
        Ok(c)
    }

    /// Host waits for the completion word.
    pub fn wait(&mut self) -> Result<()> {
        match self.mailbox.host_pop() {
            Some(_) => Ok(()),
            None => Err(Error::Device("wait: no completion pending".into())),
        }
    }

    /// Is the device binary resident (needed before any launch)?
    pub fn binary_resident(&self) -> bool {
        self.binary.is_some()
    }

    /// Abort an in-flight offload (host-side error recovery): force the
    /// cluster back to Idle and drain both mailbox FIFOs so the next
    /// launch starts clean.  No-op when nothing is in flight.
    pub fn abort(&mut self) {
        if self.state == DeviceState::Running {
            self.state = DeviceState::Idle;
        }
        while self.mailbox.device_pop().is_some() {}
        while self.mailbox.host_pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hero::offload::{OffloadArg, OffloadKind};

    fn device() -> Device {
        Device::new(&PlatformConfig::default())
    }

    fn desc() -> OffloadDescriptor {
        let mut d = OffloadDescriptor::new(OffloadKind::Gemm, (64, 64, 64), false);
        d.push_arg(OffloadArg { device_addr: 0xA000_0000, len: 1024, via_iommu: false });
        d
    }

    #[test]
    fn lifecycle_happy_path() {
        let mut dev = device();
        assert_eq!(dev.state(), DeviceState::Reset);
        let boot = dev.boot(200 * 1024, Cycles(1000)).unwrap();
        assert!(boot.0 > 1000);
        assert_eq!(dev.state(), DeviceState::Idle);
        assert!(dev.binary_resident());

        dev.launch(&desc()).unwrap();
        assert_eq!(dev.state(), DeviceState::Running);
        dev.complete().unwrap();
        dev.wait().unwrap();
        assert_eq!(dev.state(), DeviceState::Idle);
        assert_eq!(dev.launches(), 1);
    }

    #[test]
    fn launch_before_boot_rejected() {
        let mut dev = device();
        assert!(dev.launch(&desc()).is_err());
    }

    #[test]
    fn double_boot_rejected() {
        let mut dev = device();
        dev.boot(1024, Cycles(10)).unwrap();
        assert!(dev.boot(1024, Cycles(10)).is_err());
    }

    #[test]
    fn concurrent_launch_rejected() {
        let mut dev = device();
        dev.boot(1024, Cycles(10)).unwrap();
        dev.launch(&desc()).unwrap();
        assert!(dev.launch(&desc()).is_err());
    }

    #[test]
    fn wait_without_completion_fails() {
        let mut dev = device();
        dev.boot(1024, Cycles(10)).unwrap();
        dev.launch(&desc()).unwrap();
        assert!(dev.wait().is_err());
        dev.complete().unwrap();
        dev.wait().unwrap();
    }

    #[test]
    fn binary_too_big_for_l2() {
        let mut dev = device();
        let too_big = PlatformConfig::default().memory.l2_spm_bytes + 1;
        assert!(dev.boot(too_big, Cycles(0)).is_err());
        assert_eq!(dev.state(), DeviceState::Reset);
    }
}
