//! Offload descriptor ABI — what the mailbox doorbell points at.
//!
//! Mirrors HeroSDK's target-region descriptor: which device kernel to
//! run, and the device addresses + sizes of each mapped argument.  The
//! device functions themselves were copied to L2 SPM at boot (the
//! `libopenblas.so` device sections of the paper).

/// Which device kernel the descriptor invokes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OffloadKind {
    /// Heterogeneous GEMM (the paper's contributed kernel).
    Gemm,
    /// Level-2 matrix-vector product.
    Gemv,
    /// Level-1 vector kernels.
    Axpy,
    Dot,
    /// Dependent GEMM sequence with device-resident intermediates (one
    /// doorbell runs every link; see `blas::device::gemm_chain_stage`).
    Chain,
}

impl OffloadKind {
    pub fn device_symbol(self) -> &'static str {
        match self {
            OffloadKind::Gemm => "__omp_offload_gemm",
            OffloadKind::Gemv => "__omp_offload_gemv",
            OffloadKind::Axpy => "__omp_offload_axpy",
            OffloadKind::Dot => "__omp_offload_dot",
            OffloadKind::Chain => "__omp_offload_gemm_chain",
        }
    }
}

/// One mapped argument as the device sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OffloadArg {
    /// Device-visible address (dev-DRAM or IOVA in the zero-copy path).
    pub device_addr: u64,
    pub len: u64,
    /// Goes through the IOMMU (zero-copy) rather than dev DRAM?
    pub via_iommu: bool,
}

/// The descriptor posted through the mailbox.
#[derive(Debug, Clone)]
pub struct OffloadDescriptor {
    pub kind: OffloadKind,
    pub args: Vec<OffloadArg>,
    /// Problem geometry, kernel-specific: GEMM = (m, n, k); GEMV = (m, n, 0);
    /// level-1 = (n, 0, 0).
    pub dims: (usize, usize, usize),
    /// f32 fast path (paper future work)?
    pub f32_path: bool,
}

impl OffloadDescriptor {
    pub fn new(kind: OffloadKind, dims: (usize, usize, usize), f32_path: bool) -> Self {
        OffloadDescriptor { kind, args: Vec::new(), dims, f32_path }
    }

    pub fn push_arg(&mut self, arg: OffloadArg) -> &mut Self {
        self.args.push(arg);
        self
    }

    /// Total bytes the device will touch through its arguments.
    pub fn total_bytes(&self) -> u64 {
        self.args.iter().map(|a| a.len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descriptor_accumulates_args() {
        let mut d = OffloadDescriptor::new(OffloadKind::Gemm, (128, 128, 128), false);
        d.push_arg(OffloadArg { device_addr: 0xA000_0000, len: 1024, via_iommu: false });
        d.push_arg(OffloadArg { device_addr: 0x4000_0000, len: 2048, via_iommu: true });
        assert_eq!(d.args.len(), 2);
        assert_eq!(d.total_bytes(), 3072);
    }

    #[test]
    fn symbols_distinct() {
        use std::collections::HashSet;
        let kinds = [
            OffloadKind::Gemm,
            OffloadKind::Gemv,
            OffloadKind::Axpy,
            OffloadKind::Dot,
            OffloadKind::Chain,
        ];
        let syms: HashSet<_> = kinds.iter().map(|k| k.device_symbol()).collect();
        assert_eq!(syms.len(), kinds.len());
    }
}
