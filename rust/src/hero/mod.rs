//! Hero runtime — our analogue of LibHero + the Hero kernel module
//! (HeroSDK, [3] in the paper).
//!
//! Responsibilities, mirroring `hero_allocator.c` / `hero_snitch.c`:
//! device lifecycle (boot: copy device functions to L2 SPM, wake the
//! cluster), management of the two device-side arenas (L2 SPM and the
//! physically contiguous device DRAM partition), and the offload
//! descriptor ABI between host and cluster.

pub mod allocator;
pub mod device;
pub mod offload;

pub use allocator::{Allocation, Arena, ArenaStats};
pub use device::{Device, DeviceState};
pub use offload::{OffloadArg, OffloadDescriptor, OffloadKind};
