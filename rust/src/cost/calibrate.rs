//! Online calibration: EWMA-smoothed multiplicative corrections on top
//! of the analytical estimates.
//!
//! The band-matrix BLAS work (Pirova et al.) shows RISC-V BLAS tuning is
//! shape- and platform-dependent enough that hard-coded constants drift
//! wrong; rather than re-deriving the analytical model per platform, the
//! scheduler feeds every *observed* per-batch timing (already flowing
//! through `Metrics`/the trace deltas) back as an `observed / predicted`
//! ratio.  One [`Scale`] per (op family x host/device) folds those
//! ratios into an EWMA, clamped to `[floor, ceiling]` so a single
//! adversarial or degenerate sample can never swing dispatch decisions
//! outside a sane band.
//!
//! Scales live behind atomics and the whole state is shared via `Arc` —
//! every pool worker, the placement router and the batcher calibrate
//! (and read) the same model.  With `[cost] calibrate = false` the
//! scales stay at exactly 1.0 forever, so estimates are a pure function
//! of the platform description (the bit-identity configuration).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::config::CostConfig;

use super::CostOp;

/// Bound on tracked per-kernel scales — a shape-diverse stream churns
/// registry keys, and the calibration map must not outgrow the registry
/// it corrects (coldest entries are simply forgotten back to 1.0).
const MAX_KERNEL_SCALES: usize = 512;

/// One multiplicative correction factor, EWMA-smoothed and clamped.
/// Stored as f64 bits in an atomic; racy read-modify-write is fine — a
/// lost update skews a smoothed hint, never numerics.
#[derive(Debug)]
pub struct Scale(AtomicU64);

impl Scale {
    fn unit() -> Scale {
        Scale(AtomicU64::new(1.0f64.to_bits()))
    }

    /// Current correction factor (1.0 until the first observation).
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Fold one `observed / predicted` ratio in.  Non-finite or
    /// non-positive ratios are dropped (a degenerate sample must never
    /// poison the scale); finite ones are clamped before AND after the
    /// EWMA so adversarial noise is doubly bounded.
    fn fold(&self, ratio: f64, knobs: &CostConfig) {
        if !ratio.is_finite() || ratio <= 0.0 {
            return;
        }
        let sample = ratio.clamp(knobs.floor, knobs.ceiling);
        let old = self.get();
        let new = (old * (1.0 - knobs.alpha) + sample * knobs.alpha)
            .clamp(knobs.floor, knobs.ceiling);
        self.0.store(new.to_bits(), Ordering::Relaxed);
    }
}

/// Shared calibration state: one device scale and one host scale per op
/// family, indexed by [`CostOp`].
#[derive(Debug)]
pub struct Calibration {
    device: [Scale; 3],
    host: [Scale; 3],
    /// Per-kernel device scales, keyed by the kernel registry's content
    /// key: a specialized walk's observed/predicted ratio folds into
    /// its *own* EWMA, so the model learns each compiled kernel's real
    /// FPU rate instead of smearing one correction across every shape.
    kernel: Mutex<HashMap<u64, Scale>>,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration::new()
    }
}

impl Calibration {
    pub fn new() -> Calibration {
        Calibration {
            device: [Scale::unit(), Scale::unit(), Scale::unit()],
            host: [Scale::unit(), Scale::unit(), Scale::unit()],
            kernel: Mutex::new(HashMap::new()),
        }
    }

    /// Current device-path correction for an op family.
    pub fn device_scale(&self, op: CostOp) -> f64 {
        self.device[op.idx()].get()
    }

    /// Current host-path correction for an op family.
    pub fn host_scale(&self, op: CostOp) -> f64 {
        self.host[op.idx()].get()
    }

    /// Fold one observed device-path batch timing in.
    pub fn observe_device(
        &self,
        op: CostOp,
        predicted_cycles: f64,
        observed_cycles: f64,
        knobs: &CostConfig,
    ) {
        if predicted_cycles > 0.0 {
            self.device[op.idx()].fold(observed_cycles / predicted_cycles, knobs);
        }
    }

    /// Fold one observed host-path batch timing in.
    pub fn observe_host(
        &self,
        op: CostOp,
        predicted_cycles: f64,
        observed_cycles: f64,
        knobs: &CostConfig,
    ) {
        if predicted_cycles > 0.0 {
            self.host[op.idx()].fold(observed_cycles / predicted_cycles, knobs);
        }
    }

    /// Current correction for one specialized kernel (1.0 until its
    /// first observation or after a coldest-entry drop).
    pub fn kernel_scale(&self, key: u64) -> f64 {
        self.kernel
            .lock()
            .unwrap()
            .get(&key)
            .map(|s| s.get())
            .unwrap_or(1.0)
    }

    /// Tracked per-kernel scales right now.
    pub fn kernel_scales_len(&self) -> usize {
        self.kernel.lock().unwrap().len()
    }

    /// Fold one observed specialized-walk timing into the kernel's own
    /// scale.  The map is bounded: at capacity an arbitrary existing
    /// entry makes room (forgetting a scale only resets it to 1.0).
    pub fn observe_kernel(
        &self,
        key: u64,
        predicted_cycles: f64,
        observed_cycles: f64,
        knobs: &CostConfig,
    ) {
        if predicted_cycles <= 0.0 {
            return;
        }
        let mut g = self.kernel.lock().unwrap();
        if g.len() >= MAX_KERNEL_SCALES && !g.contains_key(&key) {
            if let Some(drop) = g.keys().next().copied() {
                g.remove(&drop);
            }
        }
        g.entry(key)
            .or_insert_with(Scale::unit)
            .fold(observed_cycles / predicted_cycles, knobs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knobs() -> CostConfig {
        CostConfig { calibrate: true, alpha: 0.125, floor: 0.25, ceiling: 4.0 }
    }

    #[test]
    fn scales_start_at_unity_and_converge_to_the_observed_ratio() {
        let c = Calibration::new();
        let k = knobs();
        assert_eq!(c.device_scale(CostOp::Gemm), 1.0);
        // the device consistently runs 2x slower than predicted
        for _ in 0..128 {
            c.observe_device(CostOp::Gemm, 1000.0, 2000.0, &k);
        }
        let s = c.device_scale(CostOp::Gemm);
        assert!((s - 2.0).abs() < 0.05, "device scale {s} should approach 2.0");
        // other families are untouched
        assert_eq!(c.device_scale(CostOp::Gemv), 1.0);
        assert_eq!(c.host_scale(CostOp::Gemm), 1.0);
    }

    #[test]
    fn clamps_hold_under_adversarial_noise() {
        let c = Calibration::new();
        let k = knobs();
        // absurd ratios are clamped per sample AND on the folded value
        for _ in 0..256 {
            c.observe_host(CostOp::Level1, 1.0, 1e12, &k);
        }
        assert!(c.host_scale(CostOp::Level1) <= k.ceiling);
        for _ in 0..256 {
            c.observe_host(CostOp::Level1, 1e12, 1.0, &k);
        }
        assert!(c.host_scale(CostOp::Level1) >= k.floor);
        // degenerate samples are dropped, not folded
        let before = c.device_scale(CostOp::Gemv);
        c.observe_device(CostOp::Gemv, 0.0, 100.0, &k);
        c.observe_device(CostOp::Gemv, 100.0, f64::NAN, &k);
        c.observe_device(CostOp::Gemv, 100.0, -5.0, &k);
        assert_eq!(c.device_scale(CostOp::Gemv), before);
    }

    #[test]
    fn single_outlier_moves_the_ewma_only_by_alpha() {
        let c = Calibration::new();
        let k = knobs();
        c.observe_device(CostOp::Gemm, 1000.0, 4000.0, &k); // clamped to 4.0
        let s = c.device_scale(CostOp::Gemm);
        // 1.0 * (1 - 0.125) + 4.0 * 0.125 = 1.375
        assert!((s - 1.375).abs() < 1e-9, "one sample moved scale to {s}");
    }

    #[test]
    fn kernel_scales_are_per_key_and_bounded() {
        let c = Calibration::new();
        let k = knobs();
        assert_eq!(c.kernel_scale(7), 1.0);
        // key 7 runs 2x slower than predicted; key 9 is untouched
        for _ in 0..128 {
            c.observe_kernel(7, 1000.0, 2000.0, &k);
        }
        assert!((c.kernel_scale(7) - 2.0).abs() < 0.05);
        assert_eq!(c.kernel_scale(9), 1.0);
        // degenerate predictions are dropped
        c.observe_kernel(9, 0.0, 100.0, &k);
        assert_eq!(c.kernel_scale(9), 1.0);
        // the map is bounded against key churn
        for key in 0..2 * MAX_KERNEL_SCALES as u64 {
            c.observe_kernel(key, 1000.0, 1500.0, &k);
        }
        assert!(c.kernel_scales_len() <= MAX_KERNEL_SCALES);
    }
}
