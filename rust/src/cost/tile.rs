//! Per-tile cost kernels and staged-footprint formulas — the ONE copy of
//! the DMA/FPU arithmetic that `blas::device` charges during execution
//! and [`super::model::CostModel`] sums during estimation.
//!
//! Before this module existed the same expressions lived inline in
//! `device.rs` three times (gemm, gemv, level-1) and again, re-derived,
//! in the placement router's footprint math.  Any retune had to touch
//! every copy; now the execution path and the estimator literally call
//! the same functions, so they can never drift apart.
//!
//! Everything here is a pure function of the SoC models ([`DmaModel`],
//! [`SnitchCluster`]) and the manifest tile geometry: no state, no
//! calibration — calibration is layered on top by the model.

use crate::soc::clock::Cycles;
use crate::soc::{DmaModel, SnitchCluster};

/// Round `n` up to a multiple of `m` (tile padding).
pub fn round_up(n: usize, m: usize) -> usize {
    n.div_ceil(m) * m
}

/// Steady-state costs of one GEMM tile step (see `device::gemm_compute`):
/// the A+B panel refill, the FPU burst, the C-tile transfer, and the
/// `alpha*acc + beta*c` epilogue on the resident tile.
#[derive(Debug, Clone, Copy)]
pub struct GemmTileCosts {
    /// One (A-panel + B-panel) DMA refill.
    pub dma_ab: Cycles,
    /// One tm x tn x tk FPU burst.
    pub fpu: Cycles,
    /// One C-tile DMA transfer (in or out).
    pub dma_c: Cycles,
    /// Epilogue: 2 flops/element on the resident tm x tn tile.
    pub epilogue: Cycles,
}

/// GEMM tile-step costs for a (tm, tn, tk) tile of `elem_size`-byte
/// elements.  Double-buffered steady state charges `max(dma_ab, fpu)`
/// per K step; the first step of a walk is exposed (`dma_ab + fpu`).
pub fn gemm_tile_costs(
    dma: &DmaModel,
    cluster: &SnitchCluster,
    (tm, tn, tk): (usize, usize, usize),
    elem_size: usize,
    f32_path: bool,
) -> GemmTileCosts {
    let esz = elem_size as u64;
    GemmTileCosts {
        dma_ab: dma.cost_2d(tm as u64, tk as u64 * esz)
            + dma.cost_2d(tk as u64, tn as u64 * esz),
        fpu: cluster.gemm_tile_cycles(tm, tn, tk, f32_path),
        dma_c: dma.cost_2d(tm as u64, tn as u64 * esz),
        epilogue: cluster.stream_cycles(tm * tn, 2.0, f32_path),
    }
}

/// Costs of one GEMV row-panel step (see `device::gemv_compute`):
/// level-2 is DMA-bound, each panel is streamed once against the staged
/// x matrix.
#[derive(Debug, Clone, Copy)]
pub struct GemvPanelCosts {
    /// One tm x tk A row-panel DMA refill.
    pub dma_panel: Cycles,
    /// The panel's FPU burst (2 flops/element).
    pub fpu: Cycles,
}

/// GEMV panel-step costs for a (tm, tk) panel of `elem_size`-byte
/// elements.  The charge per step is `max(dma_panel, fpu)`.
pub fn gemv_panel_costs(
    dma: &DmaModel,
    cluster: &SnitchCluster,
    (tm, tk): (usize, usize),
    elem_size: usize,
    f32_path: bool,
) -> GemvPanelCosts {
    let esz = elem_size as u64;
    GemvPanelCosts {
        dma_panel: dma.cost_2d(tm as u64, tk as u64 * esz),
        fpu: cluster.stream_cycles(tm * tk, 2.0, f32_path),
    }
}

/// Costs of one level-1 chunk step (see `device::level1_batch`): a
/// 1-D DMA burst of the chunk plus its streaming FPU cost (f64 only —
/// the artifact catalog carries f64 level-1 kernels).
#[derive(Debug, Clone, Copy)]
pub struct Level1ChunkCosts {
    /// One chunk-sized 1-D DMA burst.
    pub dma: Cycles,
    /// The chunk's FPU burst (2 flops/element).
    pub fpu: Cycles,
}

/// Level-1 chunk-step costs for an artifact-sized `chunk` of f64
/// elements.  The charge per chunk is `max(dma, fpu) + dma`.
pub fn level1_chunk_costs(
    dma: &DmaModel,
    cluster: &SnitchCluster,
    chunk: usize,
) -> Level1ChunkCosts {
    Level1ChunkCosts {
        dma: dma.cost_2d(1, (chunk * 8) as u64),
        fpu: cluster.stream_cycles(chunk, 2.0, false),
    }
}

/// Fraction of the generic tile walk's FPU burst a specialized walk
/// recovers: unrolled tile loops with baked strides and padded dims
/// drop the per-tile bounds checks, address arithmetic and epilogue
/// dispatch the interpreted walk re-derives every step, lifting the
/// cluster's sustained efficiency on the burst.  Applied uniformly to
/// every specialized op family; the per-kernel calibration scales
/// correct the residual per shape.
pub const SPECIALIZED_FPU_GAIN: f64 = 0.15;

fn specialize_fpu(fpu: Cycles) -> Cycles {
    Cycles::from_f64(fpu.0 as f64 * (1.0 - SPECIALIZED_FPU_GAIN))
}

/// Steady-state costs of one **specialized** GEMM tile step (see the
/// fast-path walk in `device::gemm_compute`): same DMA traffic as the
/// generic walk — the bytes moved are identical by construction — but a
/// leaner FPU burst and the epilogue fused into the C write-back pass
/// instead of a separate stream pass.
#[derive(Debug, Clone, Copy)]
pub struct SpecializedGemmTileCosts {
    /// One (A-panel + B-panel) DMA refill (unchanged: same bytes).
    pub dma_ab: Cycles,
    /// One unrolled tm x tn x tk FPU burst.
    pub fpu: Cycles,
    /// One C-tile DMA transfer (in or out; unchanged).
    pub dma_c: Cycles,
    /// The fused C pass: epilogue streaming overlapped with the C-tile
    /// write-back DMA (`max` instead of the generic `epilogue + dma_c`).
    pub c_pass: Cycles,
}

/// Specialized GEMM tile-step costs — the fast-path twin of
/// [`gemm_tile_costs`], charged by registry-hit walks and summed by the
/// cost model's specialized estimates.
pub fn specialized_gemm_tile_costs(
    dma: &DmaModel,
    cluster: &SnitchCluster,
    tile: (usize, usize, usize),
    elem_size: usize,
    f32_path: bool,
) -> SpecializedGemmTileCosts {
    let g = gemm_tile_costs(dma, cluster, tile, elem_size, f32_path);
    SpecializedGemmTileCosts {
        dma_ab: g.dma_ab,
        fpu: specialize_fpu(g.fpu),
        dma_c: g.dma_c,
        c_pass: g.epilogue.max(g.dma_c),
    }
}

/// Specialized GEMV panel-step costs — the fast-path twin of
/// [`gemv_panel_costs`] (level-2 stays DMA-bound; only the FPU burst
/// leans out).
pub fn specialized_gemv_panel_costs(
    dma: &DmaModel,
    cluster: &SnitchCluster,
    panel: (usize, usize),
    elem_size: usize,
    f32_path: bool,
) -> GemvPanelCosts {
    let g = gemv_panel_costs(dma, cluster, panel, elem_size, f32_path);
    GemvPanelCosts { dma_panel: g.dma_panel, fpu: specialize_fpu(g.fpu) }
}

/// Specialized level-1 chunk-step costs — the fast-path twin of
/// [`level1_chunk_costs`].
pub fn specialized_level1_chunk_costs(
    dma: &DmaModel,
    cluster: &SnitchCluster,
    chunk: usize,
) -> Level1ChunkCosts {
    let g = level1_chunk_costs(dma, cluster, chunk);
    Level1ChunkCosts { dma: g.dma, fpu: specialize_fpu(g.fpu) }
}

/// Device-DRAM bytes one staged member occupies for an (m, n, k) GEMM
/// given the manifest tile geometry and element size: three zero-padded
/// operands.  Shared by the worker's batch cap, the placement router's
/// shape routing and the model's footprint estimates, so routing can
/// never drift from what staging actually allocates.
pub fn gemm_staged_bytes_tiled(
    (tm, tn, tk): (usize, usize, usize),
    (m, n, k): (usize, usize, usize),
    elem_size: usize,
) -> u64 {
    let (mp, np, kp) = (round_up(m, tm), round_up(n, tn), round_up(k, tk));
    ((mp * kp + kp * np + mp * np) * elem_size) as u64
}

/// Device-DRAM bytes one staged GEMM *chain* occupies: `dims` is the
/// layer-width list `[d0, d1, .., dL]` (link i multiplies the running
/// (m x d_{i-1}) activation by a (d_{i-1} x d_i) weight).  Everything is
/// resident at once — the input activation, every link's weight matrix
/// and every link's output — because intermediates never return to the
/// host; the padded-operand formulas are the same ones `blas::device`
/// stages with.
pub fn chain_staged_bytes_tiled(
    (tm, tn, tk): (usize, usize, usize),
    m: usize,
    dims: &[usize],
    elem_size: usize,
) -> u64 {
    if dims.len() < 2 {
        return 0;
    }
    let mp = round_up(m, tm);
    let mut total = (mp * round_up(dims[0], tk) * elem_size) as u64; // input A
    for w in dims.windows(2) {
        let (kp, np) = (round_up(w[0], tk), round_up(w[1], tn));
        total += ((kp * np + mp * np) * elem_size) as u64; // B_i + C_i
    }
    total
}

/// Device-DRAM bytes one staged DAG occupies: the external input, every
/// matmul node's weight operand and every node's output, all resident
/// at once because interior edges never return to the host.  A linear
/// gemm DAG sums to exactly [`chain_staged_bytes_tiled`] — the executor
/// stages the identical buffers for it by construction.
pub fn dag_staged_bytes_tiled(
    (tm, tn, tk): (usize, usize, usize),
    shape: &crate::dag::DagShape,
    elem_size: usize,
) -> u64 {
    use crate::dag::DagOp;
    let mp = round_up(shape.m, tm);
    let widths = shape.widths();
    let mut total = (mp * round_up(shape.d0, tk) * elem_size) as u64; // input x
    for (i, node) in shape.nodes.iter().enumerate() {
        let k = shape.in_width(i);
        total += match node.op {
            // weight B_i (kp x np) + output C_i (mp x np)
            DagOp::Gemm => {
                let (kp, np) = (round_up(k, tk), round_up(widths[i], tn));
                ((kp * np + mp * np) * elem_size) as u64
            }
            // b column padded to one tile column + output (mp x tn)
            DagOp::Gemv => ((round_up(k, tk) * tn + mp * tn) * elem_size) as u64,
            // fan-in over resident buffers: only the output is new
            DagOp::Axpy => (mp * round_up(widths[i], tn) * elem_size) as u64,
            // scalar sink, held in one padded tile
            DagOp::Dot => (tm * tn * elem_size) as u64,
        };
    }
    total
}

/// Device-DRAM bytes one staged member occupies for an (m, n) GEMV —
/// the padded A matrix, the tile-width x matrix and the y vector.
pub fn gemv_staged_bytes_tiled(
    (tm, tn, tk): (usize, usize, usize),
    (m, n): (usize, usize),
    elem_size: usize,
) -> u64 {
    let (mp, np) = (round_up(m, tm), round_up(n, tk));
    ((mp * np + np * tn + mp) * elem_size) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;

    fn models() -> (DmaModel, SnitchCluster) {
        let cfg = PlatformConfig::default();
        (
            DmaModel::new(cfg.dma.clone()),
            SnitchCluster::new(cfg.cluster.clone(), cfg.memory.l1_spm_bytes),
        )
    }

    #[test]
    fn gemm_tile_costs_match_the_soc_models() {
        let (dma, cluster) = models();
        let t = gemm_tile_costs(&dma, &cluster, (64, 64, 64), 8, false);
        // one 64x512B panel is 4402 cycles (see soc::dma tests); A+B = 2x
        assert_eq!(t.dma_ab, Cycles(8804));
        assert_eq!(t.dma_c, Cycles(4402));
        assert_eq!(t.fpu, cluster.gemm_tile_cycles(64, 64, 64, false));
        assert_eq!(t.epilogue, cluster.stream_cycles(64 * 64, 2.0, false));
    }

    #[test]
    fn gemv_and_level1_costs_match_the_soc_models() {
        let (dma, cluster) = models();
        let g = gemv_panel_costs(&dma, &cluster, (64, 64), 8, false);
        assert_eq!(g.dma_panel, dma.cost_2d(64, 512));
        assert_eq!(g.fpu, cluster.stream_cycles(64 * 64, 2.0, false));
        let l = level1_chunk_costs(&dma, &cluster, 4096);
        assert_eq!(l.dma, dma.cost_2d(1, 4096 * 8));
        assert_eq!(l.fpu, cluster.stream_cycles(4096, 2.0, false));
    }

    #[test]
    fn specialized_costs_undercut_generic_without_touching_dma() {
        let (dma, cluster) = models();
        let g = gemm_tile_costs(&dma, &cluster, (64, 64, 64), 8, false);
        let s = specialized_gemm_tile_costs(&dma, &cluster, (64, 64, 64), 8, false);
        // the bytes moved are identical: DMA charges must not change
        assert_eq!(s.dma_ab, g.dma_ab);
        assert_eq!(s.dma_c, g.dma_c);
        // the unrolled burst is leaner and the epilogue fuses into the
        // C pass instead of serializing after it
        assert!(s.fpu < g.fpu);
        assert_eq!(
            s.fpu,
            Cycles::from_f64(g.fpu.0 as f64 * (1.0 - SPECIALIZED_FPU_GAIN))
        );
        assert_eq!(s.c_pass, g.epilogue.max(g.dma_c));
        assert!(s.c_pass < g.epilogue + g.dma_c);

        let gv = gemv_panel_costs(&dma, &cluster, (64, 64), 8, false);
        let sv = specialized_gemv_panel_costs(&dma, &cluster, (64, 64), 8, false);
        assert_eq!(sv.dma_panel, gv.dma_panel);
        assert!(sv.fpu < gv.fpu);

        let gl = level1_chunk_costs(&dma, &cluster, 4096);
        let sl = specialized_level1_chunk_costs(&dma, &cluster, 4096);
        assert_eq!(sl.dma, gl.dma);
        assert!(sl.fpu < gl.fpu);
    }

    #[test]
    fn staged_bytes_pad_to_the_tile() {
        let tile = (64, 64, 64);
        // exact multiples: 3 * n^2 * 8
        assert_eq!(
            gemm_staged_bytes_tiled(tile, (128, 128, 128), 8),
            3 * 128 * 128 * 8
        );
        // 65 pads to 128 in every dim
        assert_eq!(
            gemm_staged_bytes_tiled(tile, (65, 65, 65), 8),
            3 * 128 * 128 * 8
        );
        // gemv: padded A + x matrix (np x tn) + y (mp)
        assert_eq!(
            gemv_staged_bytes_tiled(tile, (128, 128), 8),
            (128 * 128 + 128 * 64 + 128) * 8
        );
    }

    #[test]
    fn chain_staged_bytes_sum_shared_activations_once() {
        let tile = (64, 64, 64);
        // one link degenerates to the plain gemm footprint
        assert_eq!(
            chain_staged_bytes_tiled(tile, 128, &[128, 128], 8),
            gemm_staged_bytes_tiled(tile, (128, 128, 128), 8)
        );
        // two links: A1 + (B1 + C1) + (B2 + C2); C1 doubles as A2 and is
        // counted once
        assert_eq!(
            chain_staged_bytes_tiled(tile, 64, &[64, 64, 64], 8),
            ((64 * 64) + 2 * (64 * 64 + 64 * 64)) as u64 * 8
        );
        // degenerate specs stage nothing
        assert_eq!(chain_staged_bytes_tiled(tile, 64, &[64], 8), 0);
        assert_eq!(chain_staged_bytes_tiled(tile, 64, &[], 8), 0);
    }

    #[test]
    fn dag_staged_bytes_match_the_chain_for_linear_specs() {
        use crate::dag::{linear_gemm_shape, DagNodeShape, DagOp, DagShape};
        let tile = (64, 64, 64);
        // a linear gemm DAG stages exactly what the chain stages
        for dims in [vec![64, 64, 64], vec![128, 96, 32], vec![65, 65]] {
            let s = linear_gemm_shape(70, &dims);
            assert_eq!(
                dag_staged_bytes_tiled(tile, &s, 8),
                chain_staged_bytes_tiled(tile, 70, &dims, 8)
            );
        }
        // fan-out shares the trunk: two heads off one trunk stage the
        // trunk's output once — x + (B0+C0) + 2x(B+C heads)
        let s = DagShape {
            m: 64,
            d0: 64,
            nodes: vec![
                DagNodeShape {
                    op: DagOp::Gemm,
                    src: None,
                    src2: None,
                    n: 64,
                    bias: false,
                    relu: false,
                },
                DagNodeShape {
                    op: DagOp::Gemm,
                    src: Some(0),
                    src2: None,
                    n: 64,
                    bias: false,
                    relu: false,
                },
                DagNodeShape {
                    op: DagOp::Gemv,
                    src: Some(0),
                    src2: None,
                    n: 0,
                    bias: false,
                    relu: false,
                },
            ],
        };
        let x = 64 * 64 * 8u64;
        assert_eq!(
            dag_staged_bytes_tiled(tile, &s, 8),
            x + 2 * x + 2 * x + (64 * 64 + 64 * 64) * 8
        );
    }
}
