//! Unified offload cost model: one calibrated estimator behind
//! dispatch, batching, placement, and pipelining.
//!
//! The paper's central engineering fact is the Figure-3 crossover — the
//! fixed fork-join + partition-copy cost makes offload *lose* below a
//! problem size — and before this module the codebase encoded that fact
//! five separate times: static thresholds in `blas::dispatch`, three
//! hand-rolled DMA/FPU cost blocks in `blas::device`, padded-footprint
//! math in `sched::placement`, linger heuristics in `sched::batcher`,
//! and the overlap credit in `sched::worker`.  Five copies of one truth
//! meant five constants to re-tune per platform; HERO's offload-cost
//! structure (mailbox + DMA + fork-join) is regular enough to capture
//! analytically *once*, and RISC-V BLAS tuning is platform-dependent
//! enough that the capture must be corrected online.
//!
//! Three layers:
//!
//! * [`tile`] — the per-tile DMA/FPU cost kernels and staged-footprint
//!   formulas, called by `blas::device` while *charging* execution and
//!   by the model while *estimating* (so they cannot drift);
//! * [`model`] — [`CostModel`]: per-call device-vs-host estimates that
//!   mirror the engine's actual charges (fork-join fixed cycles, map-in
//!   bytes at the copy bandwidth with cache/alloc elisions, the tile
//!   walk), plus the derived surfaces each consumer needs: dispatch
//!   decisions (cache-aware via predicted operand residency), live
//!   crossover estimates, the batcher's linger-amortization curve, the
//!   router's staged footprints and the pipelining overlap credit;
//! * [`calibrate`] — EWMA feedback from observed per-op timings (the
//!   trace deltas already flowing through `Metrics`), clamped so noise
//!   cannot swing decisions outside a sane band.  `[cost]` in the
//!   platform TOML holds the knobs; `calibrate = false` (the default)
//!   pins every scale at 1.0 so estimates — and with them every
//!   dispatch decision — are a pure function of the platform
//!   description.

pub mod calibrate;
pub mod model;
pub mod tile;

pub use calibrate::Calibration;
pub use model::{CostModel, Crossovers};
pub use tile::{
    chain_staged_bytes_tiled, dag_staged_bytes_tiled, gemm_staged_bytes_tiled,
    gemm_tile_costs,
    gemv_panel_costs, gemv_staged_bytes_tiled, level1_chunk_costs, round_up,
    specialized_gemm_tile_costs, specialized_gemv_panel_costs,
    specialized_level1_chunk_costs, GemmTileCosts, GemvPanelCosts,
    Level1ChunkCosts, SpecializedGemmTileCosts, SPECIALIZED_FPU_GAIN,
};

/// Op families the model estimates; indexes the calibration scales.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CostOp {
    Gemm,
    Gemv,
    Level1,
}

impl CostOp {
    /// Scale-array index.
    pub fn idx(self) -> usize {
        match self {
            CostOp::Gemm => 0,
            CostOp::Gemv => 1,
            CostOp::Level1 => 2,
        }
    }

    /// Family of a batch-key / serve-protocol op name.
    pub fn from_name(op: &str) -> Option<CostOp> {
        match op {
            "gemm" => Some(CostOp::Gemm),
            "gemv" => Some(CostOp::Gemv),
            "axpy" | "dot" => Some(CostOp::Level1),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_op_names_and_indices() {
        assert_eq!(CostOp::from_name("gemm"), Some(CostOp::Gemm));
        assert_eq!(CostOp::from_name("gemv"), Some(CostOp::Gemv));
        assert_eq!(CostOp::from_name("axpy"), Some(CostOp::Level1));
        assert_eq!(CostOp::from_name("dot"), Some(CostOp::Level1));
        assert_eq!(CostOp::from_name("fence"), None);
        assert_eq!(CostOp::Gemm.idx(), 0);
        assert_eq!(CostOp::Gemv.idx(), 1);
        assert_eq!(CostOp::Level1.idx(), 2);
    }
}
