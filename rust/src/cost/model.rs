//! The calibrated offload cost model.
//!
//! [`CostModel`] answers ONE question for every consumer — "what does
//! this call cost on the device path vs the host path?" — by summing
//! the same per-region charges the offload engine will actually make:
//!
//! * **fork-join**: the fixed OpenBLAS + libomptarget entry, descriptor
//!   marshalling (per mapped argument), doorbell, device wake-up,
//!   completion doorbell, join and exit — the paper's size-independent
//!   overhead that makes offload *lose* below the Figure-3 crossover;
//! * **data copy**: `map(to:)`/`map(from:)` of the user's bytes at the
//!   host's partition-copy bandwidth, with the operand-cache and
//!   `map(alloc:)` elisions applied when the config enables them — a
//!   *predicted cache hit* (operand already device-resident, per the
//!   affinity directory) drops an operand's map-in to the memcpy setup
//!   cost, which is what lets warm shared-B streams offload below the
//!   cold crossover;
//! * **compute**: the double-buffered tile walk from the shared
//!   [`super::tile`] kernels — the very functions `blas::device`
//!   charges during execution, so estimate and execution cannot drift.
//!
//! Host cost comes from the same [`Cva6Model`] the host kernels charge.
//! On top sits the EWMA [`Calibration`] (shared via `Arc` across every
//! clone, so a whole scheduler pool calibrates one model): observed
//! batch timings scale the estimates within clamped bounds.  Consumers:
//! dispatch (`DispatchPolicy::Auto`), the batcher's linger sizing, the
//! placement router's footprint/lane routing, and the worker's
//! pipelining overlap credit.

use std::sync::Arc;
use std::time::Duration;

use crate::config::{CostConfig, DispatchMode, ForkJoinConfig, PlatformConfig};
use crate::dag::{DagOp, DagShape};
use crate::runtime::Manifest;
use crate::soc::{Cva6Model, DmaModel, SnitchCluster};

use super::calibrate::Calibration;
use super::tile::{
    self, gemm_staged_bytes_tiled, gemv_staged_bytes_tiled, round_up,
};
use super::CostOp;

/// Fallback level-1 chunk length when the manifest carries no level-1
/// artifacts (estimates still need a chunk size; the device path itself
/// would fail cleanly before any estimate mattered).
const DEFAULT_LEVEL1_CHUNK: usize = 4096;

/// Serve-protocol shape bound — the crossover searches scan up to here.
const MAX_DIM: usize = 2048;
const MAX_LEVEL1_N: usize = 1 << 20;

/// Live crossover estimates per op: the smallest problem size at which
/// the (calibrated) model predicts the device path wins.  `None` means
/// the device never wins inside the serve-protocol shape bounds — true
/// for cold level-2/level-1 in copy mode, where the partition copy alone
/// outweighs the host FLOPs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Crossovers {
    /// Square f64 GEMM, all operands cold.
    pub gemm_n: Option<usize>,
    /// Square f64 GEMM with B predicted cache-resident (warm stream).
    pub gemm_warm_n: Option<usize>,
    /// Square f64 GEMV (m = n), cold.
    pub gemv_n: Option<usize>,
    /// f64 AXPY length, cold.
    pub level1_n: Option<usize>,
    /// Square f64 GEMM through a registry-specialized walk, cold
    /// operands — the dual crossover line next to `gemm_n`.
    pub gemm_spec_n: Option<usize>,
    /// Square f64 GEMV through a specialized walk, cold.
    pub gemv_spec_n: Option<usize>,
    /// f64 AXPY length through a specialized walk, cold.
    pub level1_spec_n: Option<usize>,
}

/// The unified, online-calibrated offload cost estimator.  Cheap to
/// clone; clones share calibration state.
#[derive(Debug, Clone)]
pub struct CostModel {
    freq_hz: u64,
    fj: ForkJoinConfig,
    host: Cva6Model,
    cluster: SnitchCluster,
    dma: DmaModel,
    /// Manifest tile geometry (pads exactly like the staging path).
    tile: (usize, usize, usize),
    /// Largest level-1 artifact length (the device chunk size).
    level1_chunk: usize,
    /// Intra-offload compute clusters (output tiles round-robin).
    intra_clusters: usize,
    /// Do the operand-cache staging elisions apply (`[sched.cache]`)?
    cache_enabled: bool,
    knobs: CostConfig,
    calib: Arc<Calibration>,
}

impl CostModel {
    /// Build from a platform description plus the manifest-derived
    /// geometry (tile shape, largest level-1 artifact).
    pub fn from_platform(
        cfg: &PlatformConfig,
        tile: (usize, usize, usize),
        level1_chunk: usize,
    ) -> CostModel {
        CostModel {
            freq_hz: cfg.clock.freq_hz,
            fj: cfg.forkjoin.clone(),
            host: Cva6Model::new(cfg.host.clone()),
            cluster: SnitchCluster::new(cfg.cluster.clone(), cfg.memory.l1_spm_bytes),
            dma: DmaModel::new(cfg.dma.clone()),
            tile,
            level1_chunk: level1_chunk.max(1),
            intra_clusters: (cfg.cluster.clusters as usize).max(1),
            cache_enabled: cfg.sched.cache.cache_enabled(),
            knobs: cfg.cost.clone(),
            calib: Arc::new(Calibration::new()),
        }
    }

    /// Build from a platform description and a loaded manifest.
    pub fn from_manifest(cfg: &PlatformConfig, man: &Manifest) -> CostModel {
        let chunk = man
            .entries
            .iter()
            .filter(|e| (e.op == "axpy" || e.op == "dot") && e.dtype == "f64")
            .filter_map(|e| e.n)
            .max()
            .unwrap_or(DEFAULT_LEVEL1_CHUNK);
        CostModel::from_platform(cfg, (man.tile_m, man.tile_n, man.tile_k), chunk)
    }

    /// Is online calibration active (`[cost] calibrate`)?
    pub fn calibrate_enabled(&self) -> bool {
        self.knobs.calibrate
    }

    /// The shared calibration state (scales read by tests/reporting).
    pub fn calibration(&self) -> &Calibration {
        &self.calib
    }

    // ------------------------------------------------------------------
    // Raw (uncalibrated) per-call estimates, in cycles
    // ------------------------------------------------------------------

    fn memcpy(&self, bytes: u64) -> f64 {
        self.host.memcpy_cycles(bytes).0 as f64
    }

    fn memcpy_setup(&self) -> f64 {
        // the charge of a cache hit / map(alloc:) staging elision
        self.memcpy(0)
    }

    /// Fixed fork-join cycles of one launch, excluding the per-argument
    /// marshalling (which scales with batch members and is therefore not
    /// amortized by batching).
    fn forkjoin_shared(&self) -> f64 {
        (self.fj.openblas_entry_cycles
            + self.fj.omp_entry_cycles
            + self.fj.doorbell_cycles      // launch doorbell
            + self.fj.device_wakeup_cycles
            + self.fj.doorbell_cycles      // completion doorbell back
            + self.fj.join_cycles
            + self.fj.exit_cycles) as f64
    }

    /// Predicted cycles for one coalesced device GEMM launch of `batch`
    /// members of op-shape (m, n, k), f64.  `warm_b` predicts the B
    /// operand cache-resident (map-in drops to the setup cost);
    /// `beta_zero` applies the `map(alloc:)` output-staging elision when
    /// the cache config enables it.
    pub fn offload_gemm_cycles(
        &self,
        (m, n, k): (usize, usize, usize),
        batch: usize,
        warm_b: bool,
        beta_zero: bool,
    ) -> f64 {
        self.offload_gemm_cycles_walk((m, n, k), batch, warm_b, beta_zero, false)
    }

    /// Specialized-walk twin of [`CostModel::offload_gemm_cycles`]: the
    /// same fork-join and map traffic (the bytes moved are identical by
    /// construction) over the registry's fast-path tile schedule.
    pub fn offload_gemm_cycles_spec(
        &self,
        dims: (usize, usize, usize),
        batch: usize,
        warm_b: bool,
        beta_zero: bool,
    ) -> f64 {
        self.offload_gemm_cycles_walk(dims, batch, warm_b, beta_zero, true)
    }

    fn offload_gemm_cycles_walk(
        &self,
        (m, n, k): (usize, usize, usize),
        batch: usize,
        warm_b: bool,
        beta_zero: bool,
        spec: bool,
    ) -> f64 {
        let batch = batch.max(1);
        let esz = 8u64;

        let fork = self.forkjoin_shared()
            + (self.fj.per_arg_cycles * 3 * batch as u64) as f64;

        let a_in = self.memcpy((m * k) as u64 * esz);
        let b_in = if warm_b && self.cache_enabled {
            self.memcpy_setup()
        } else {
            self.memcpy((k * n) as u64 * esz)
        };
        let c_in = if beta_zero && self.cache_enabled {
            self.memcpy_setup()
        } else {
            self.memcpy((m * n) as u64 * esz)
        };
        let c_out = self.memcpy((m * n) as u64 * esz);

        let walk = if spec {
            self.gemm_walk_cycles_spec((m, n, k), beta_zero)
        } else {
            self.gemm_walk_cycles((m, n, k), beta_zero)
        };
        fork + batch as f64 * (a_in + b_in + c_in + c_out + walk)
    }

    /// Compute-region cycles of one device GEMM's tile walk (the
    /// double-buffered DMA/FPU schedule over the padded grid), excluding
    /// every map cost — shared between the single-op and chain estimates.
    fn gemm_walk_cycles(&self, (m, n, k): (usize, usize, usize), beta_zero: bool) -> f64 {
        let (tm, tn, tk) = self.tile;
        let (mp, np, kp) = (round_up(m, tm), round_up(n, tn), round_up(k, tk));
        let (gm, gn, gk) = (mp / tm, np / tn, kp / tk);
        let t = tile::gemm_tile_costs(&self.dma, &self.cluster, (tm, tn, tk), 8, false);
        let steady = t.dma_ab.max(t.fpu).0 as f64;
        let per_walk = (t.dma_ab + t.fpu).0 as f64
            + (gk.saturating_sub(1)) as f64 * steady
            + if beta_zero { 0.0 } else { t.dma_c.0 as f64 }
            + (t.epilogue + t.dma_c).0 as f64;
        (gm * gn).div_ceil(self.intra_clusters) as f64 * per_walk
    }

    /// The specialized-walk cycle formula: the per-step charges a
    /// registry plan bakes (leaner unrolled FPU burst, epilogue fused
    /// into the C write-back pass) summed over the same padded grid.
    /// Mirrors `KernelPlan::specialize` exactly — both read the shared
    /// [`tile::specialized_gemm_tile_costs`].
    fn gemm_walk_cycles_spec(
        &self,
        (m, n, k): (usize, usize, usize),
        beta_zero: bool,
    ) -> f64 {
        let (tm, tn, tk) = self.tile;
        let (mp, np, kp) = (round_up(m, tm), round_up(n, tn), round_up(k, tk));
        let (gm, gn, gk) = (mp / tm, np / tn, kp / tk);
        let s = tile::specialized_gemm_tile_costs(
            &self.dma,
            &self.cluster,
            (tm, tn, tk),
            8,
            false,
        );
        let steady = s.dma_ab.max(s.fpu).0 as f64;
        let per_walk = (s.dma_ab + s.fpu).0 as f64
            + (gk.saturating_sub(1)) as f64 * steady
            + if beta_zero { 0.0 } else { s.dma_c.0 as f64 }
            + s.c_pass.0 as f64;
        (gm * gn).div_ceil(self.intra_clusters) as f64 * per_walk
    }

    /// Predicted cycles for one device GEMM *chain* launch: `dims` is the
    /// layer-width list `[d0, .., dL]` (link i multiplies the running
    /// (m x d_{i-1}) activation by a (d_{i-1} x d_i) weight, beta = 0).
    /// ONE fork-join covers every link; only the first activation copies
    /// in and only the last result copies out — each intermediate costs
    /// two bookkeeping setups (`chain_keep` + `chain_reuse`) instead of a
    /// map-out + map-in round trip.  This is what makes the device win
    /// for chains whose individual links sit below the cold crossover.
    pub fn offload_chain_cycles(&self, m: usize, dims: &[usize]) -> f64 {
        if dims.len() < 2 {
            return 0.0;
        }
        let links = dims.len() - 1;
        let esz = 8u64;
        let mut total = self.forkjoin_shared()
            + (self.fj.per_arg_cycles * (1 + 2 * links as u64)) as f64;
        total += self.memcpy((m * dims[0]) as u64 * esz); // first activation in
        for (i, w) in dims.windows(2).enumerate() {
            let (k, n) = (w[0], w[1]);
            total += self.memcpy((k * n) as u64 * esz); // B_i in (cold)
            total += self.memcpy_setup(); // C_i staged map(alloc:)-style
            total += self.gemm_walk_cycles((m, n, k), true);
            if i + 1 < links {
                // intermediate hand-off: chain_keep + chain_reuse
                total += 2.0 * self.memcpy_setup();
            }
        }
        total += self.memcpy((m * dims[links]) as u64 * esz); // final C out
        total
    }

    /// Predicted cycles for the same chain on the host path (one host
    /// GEMM per link; the epilogues are negligible and identical on both
    /// paths).
    pub fn host_chain_cycles(&self, m: usize, dims: &[usize]) -> f64 {
        dims.windows(2)
            .map(|w| self.host.gemm_cycles(m, w[1], w[0], false).0 as f64)
            .sum()
    }

    /// Staged device-DRAM footprint of an f64 GEMM chain (everything is
    /// resident at once — see [`tile::chain_staged_bytes_tiled`]).
    pub fn chain_staged_bytes(&self, m: usize, dims: &[usize]) -> u64 {
        tile::chain_staged_bytes_tiled(self.tile, m, dims, 8)
    }

    /// Does the device path win an f64 GEMM chain?  Calibrated with the
    /// GEMM scales — a chain is GEMM traffic with its interior copies
    /// elided.
    pub fn device_wins_chain(&self, m: usize, dims: &[usize]) -> bool {
        if dims.len() < 2 {
            return false;
        }
        self.scaled_device(CostOp::Gemm, self.offload_chain_cycles(m, dims))
            < self.scaled_host(CostOp::Gemm, self.host_chain_cycles(m, dims))
    }

    /// The chain arm of the shared mode-to-path mapping (see
    /// [`CostModel::decides_device`]).  Forced device modes answer true —
    /// chained residency is a copy-mode technique, so a zero-copy forcing
    /// still runs the copy-mode chain path.
    pub fn decides_device_chain(
        &self,
        m: usize,
        dims: &[usize],
        mode: DispatchMode,
    ) -> bool {
        match mode {
            DispatchMode::HostOnly => false,
            DispatchMode::DeviceOnly | DispatchMode::DeviceZeroCopy => true,
            DispatchMode::Auto => self.device_wins_chain(m, dims),
        }
    }

    /// One streamed level-1-style pass over `n` elements (the fan-in and
    /// epilogue charge shape: stream in, FPU, stream out) — the exact
    /// formula `blas::device` charges for `dag_axpy`/`dag_dot` and the
    /// unfused `chain_epilogue`.
    fn level1_pass_cycles(&self, n: usize) -> f64 {
        let c = tile::level1_chunk_costs(&self.dma, &self.cluster, n);
        (c.dma.max(c.fpu) + c.dma).0 as f64
    }

    /// Compute cycles of node `i`'s walk alone (no epilogue): the tile
    /// walk for matmul nodes, the streamed fan-in pass for axpy/dot.
    fn dag_node_compute_cycles(&self, shape: &DagShape, i: usize) -> f64 {
        let node = &shape.nodes[i];
        let k = shape.in_width(i);
        match node.op {
            DagOp::Gemm => self.gemm_walk_cycles((shape.m, node.n, k), true),
            DagOp::Gemv => self.gemm_walk_cycles((shape.m, 1, k), true),
            DagOp::Axpy | DagOp::Dot => self.level1_pass_cycles(shape.m * k),
        }
    }

    /// Predicted compute-region cycles of DAG node `i`: the walk plus the
    /// epilogue pass when the node declares one — the same charges the
    /// executor makes between its per-node trace snapshots, so an
    /// observed per-node delta divides by this prediction cleanly (see
    /// [`CostModel::observe_dag_nodes`]).
    pub fn dag_node_walk_cycles(&self, shape: &DagShape, i: usize) -> f64 {
        let node = &shape.nodes[i];
        let mut cycles = self.dag_node_compute_cycles(shape, i);
        if node.bias || node.relu {
            cycles += self.level1_pass_cycles(shape.m * shape.widths()[i]);
        }
        cycles
    }

    /// Predicted cycles for one device *DAG* launch: ONE fork-join covers
    /// every node; the external activation copies in once, only sink
    /// outputs copy out, and every interior edge costs one `dag_keep`
    /// plus one `dag_reuse` setup per consumer instead of a map-out +
    /// map-in round trip.  For a linear gemm-only DAG this is — charge
    /// for charge — [`CostModel::offload_chain_cycles`].
    pub fn offload_dag_cycles(&self, shape: &DagShape) -> f64 {
        if shape.nodes.is_empty() {
            return 0.0;
        }
        let esz = 8u64;
        let widths = shape.widths();
        let consumers = shape.consumer_counts();
        let mut total = self.forkjoin_shared()
            + (self.fj.per_arg_cycles * shape.marshalled_args() as u64) as f64;
        total += self.memcpy((shape.m * shape.d0) as u64 * esz); // x in
        for (i, node) in shape.nodes.iter().enumerate() {
            if node.op.is_matmul() {
                let k = shape.in_width(i);
                total += self.memcpy((k * widths[i]) as u64 * esz); // B_i in (cold)
            }
            total += self.memcpy_setup(); // C_i staged map(alloc:)-style
            total += self.dag_node_compute_cycles(shape, i);
            if consumers[i] > 0 {
                // resident hand-off: dag_keep once + dag_reuse per consumer
                total += (1 + consumers[i]) as f64 * self.memcpy_setup();
            }
        }
        for s in shape.sinks() {
            let (om, on) = shape.out_dims(s);
            total += self.memcpy((om * on) as u64 * esz); // sink C out
        }
        total
    }

    /// Predicted cycles for the same DAG on the host path (one host call
    /// per node; the epilogues are negligible and identical on both
    /// paths, as for chains).
    pub fn host_dag_cycles(&self, shape: &DagShape) -> f64 {
        (0..shape.nodes.len())
            .map(|i| self.host_dag_node_cycles(shape, i))
            .sum()
    }

    fn host_dag_node_cycles(&self, shape: &DagShape, i: usize) -> f64 {
        let node = &shape.nodes[i];
        let k = shape.in_width(i);
        match node.op {
            DagOp::Gemm => self.host.gemm_cycles(shape.m, node.n, k, false).0 as f64,
            DagOp::Gemv => self.host.gemv_cycles(shape.m, k, false).0 as f64,
            DagOp::Axpy | DagOp::Dot => {
                self.host.level1_cycles(shape.m * k, 2.0, false).0 as f64
            }
        }
    }

    /// Staged device-DRAM footprint of an f64 DAG (everything resident at
    /// once — see [`tile::dag_staged_bytes_tiled`]).
    pub fn dag_staged_bytes(&self, shape: &DagShape) -> u64 {
        tile::dag_staged_bytes_tiled(self.tile, shape, 8)
    }

    /// Does the device path win an f64 DAG?  Each node's walk is scaled
    /// by its own op family's calibration; the shared charges (fork-join,
    /// maps, hand-off setups) ride under the GEMM scales, since matmul
    /// trunks dominate every DAG worth offloading.  For an all-gemm
    /// linear DAG the comparison reduces exactly to
    /// [`CostModel::device_wins_chain`]'s.
    pub fn device_wins_dag(&self, shape: &DagShape) -> bool {
        if shape.nodes.is_empty() {
            return false;
        }
        let mut shared = self.offload_dag_cycles(shape);
        let mut device = 0.0;
        let mut host = 0.0;
        for (i, node) in shape.nodes.iter().enumerate() {
            let fam = dag_family(node.op);
            let walk = self.dag_node_compute_cycles(shape, i);
            shared -= walk;
            device += self.scaled_device(fam, walk);
            host += self.scaled_host(fam, self.host_dag_node_cycles(shape, i));
        }
        device += self.scaled_device(CostOp::Gemm, shared);
        device < host
    }

    /// The DAG arm of the shared mode-to-path mapping (see
    /// [`CostModel::decides_device`]).  Graph residency is a copy-mode
    /// technique, so a zero-copy forcing still runs the copy-mode path.
    pub fn decides_device_dag(&self, shape: &DagShape, mode: DispatchMode) -> bool {
        match mode {
            DispatchMode::HostOnly => false,
            DispatchMode::DeviceOnly | DispatchMode::DeviceZeroCopy => true,
            DispatchMode::Auto => self.device_wins_dag(shape),
        }
    }

    /// Per-node DAG feedback — the per-link attribution that whole-launch
    /// [`CostModel::observe_chain`] skips.  `node_cycles` are the
    /// executor's per-node compute-region trace deltas; each divides by
    /// its own node's predicted walk and folds into that node's op-family
    /// device scale, so a mixed DAG calibrates gemm, gemv and level-1
    /// independently from ONE launch.
    pub fn observe_dag_nodes(&self, shape: &DagShape, node_cycles: &[u64]) {
        if !self.knobs.calibrate || node_cycles.len() != shape.nodes.len() {
            return;
        }
        for (i, (node, &observed)) in
            shape.nodes.iter().zip(node_cycles).enumerate()
        {
            if observed == 0 {
                continue;
            }
            self.calib.observe_device(
                dag_family(node.op),
                self.dag_node_walk_cycles(shape, i),
                observed as f64,
                &self.knobs,
            );
        }
    }

    /// Host-path DAG feedback: the whole-launch timing apportioned by
    /// each present family's predicted share (the host path has no
    /// per-node trace seam).
    pub fn observe_dag_host(&self, shape: &DagShape, observed_cycles: u64) {
        if !self.knobs.calibrate || observed_cycles == 0 || shape.nodes.is_empty() {
            return;
        }
        let total = self.host_dag_cycles(shape);
        if total <= 0.0 {
            return;
        }
        let ratio = observed_cycles as f64 / total;
        let mut seen = [false; 3];
        for node in &shape.nodes {
            let fam = dag_family(node.op);
            if !seen[fam.idx()] {
                seen[fam.idx()] = true;
                // fold observed/predicted once per family present
                self.calib.observe_host(fam, 1.0, ratio, &self.knobs);
            }
        }
    }

    /// Predicted cycles for the same GEMM batch on the host path.
    pub fn host_gemm_cycles(&self, (m, n, k): (usize, usize, usize), batch: usize) -> f64 {
        batch.max(1) as f64 * self.host.gemm_cycles(m, n, k, false).0 as f64
    }

    /// Predicted cycles for one coalesced device GEMV launch (f64).
    pub fn offload_gemv_cycles(
        &self,
        (m, n): (usize, usize),
        batch: usize,
        beta_zero: bool,
    ) -> f64 {
        self.offload_gemv_cycles_walk((m, n), batch, beta_zero, false)
    }

    /// Specialized-walk twin of [`CostModel::offload_gemv_cycles`].
    pub fn offload_gemv_cycles_spec(
        &self,
        dims: (usize, usize),
        batch: usize,
        beta_zero: bool,
    ) -> f64 {
        self.offload_gemv_cycles_walk(dims, batch, beta_zero, true)
    }

    fn offload_gemv_cycles_walk(
        &self,
        (m, n): (usize, usize),
        batch: usize,
        beta_zero: bool,
        spec: bool,
    ) -> f64 {
        let batch = batch.max(1);
        let (tm, _tn, tk) = self.tile;
        let (mp, np) = (round_up(m, tm), round_up(n, tk));
        let (gm, gk) = (mp / tm, np / tk);
        let esz = 8u64;

        let fork = self.forkjoin_shared()
            + (self.fj.per_arg_cycles * 3 * batch as u64) as f64;
        let a_in = self.memcpy((m * n) as u64 * esz);
        let x_in = self.memcpy(n as u64 * esz);
        let y_in = if beta_zero && self.cache_enabled {
            self.memcpy_setup()
        } else {
            self.memcpy(m as u64 * esz)
        };
        let y_out = self.memcpy(m as u64 * esz);

        let p = if spec {
            tile::specialized_gemv_panel_costs(&self.dma, &self.cluster, (tm, tk), 8, false)
        } else {
            tile::gemv_panel_costs(&self.dma, &self.cluster, (tm, tk), 8, false)
        };
        let compute = (gm * gk) as f64 * p.dma_panel.max(p.fpu).0 as f64;

        fork + batch as f64 * (a_in + x_in + y_in + y_out + compute)
    }

    /// Predicted cycles for the same GEMV batch on the host path.
    pub fn host_gemv_cycles(&self, (m, n): (usize, usize), batch: usize) -> f64 {
        batch.max(1) as f64 * self.host.gemv_cycles(m, n, false).0 as f64
    }

    /// Predicted cycles for one coalesced device level-1 launch (axpy or
    /// dot, length n, f64).
    pub fn offload_level1_cycles(&self, n: usize, batch: usize, is_axpy: bool) -> f64 {
        self.offload_level1_cycles_walk(n, batch, is_axpy, false)
    }

    /// Specialized-walk twin of [`CostModel::offload_level1_cycles`].
    pub fn offload_level1_cycles_spec(
        &self,
        n: usize,
        batch: usize,
        is_axpy: bool,
    ) -> f64 {
        self.offload_level1_cycles_walk(n, batch, is_axpy, true)
    }

    fn offload_level1_cycles_walk(
        &self,
        n: usize,
        batch: usize,
        is_axpy: bool,
        spec: bool,
    ) -> f64 {
        let batch = batch.max(1);
        let chunk = self.level1_chunk;
        let nargs = if is_axpy { 3 } else { 2 };
        let fork = self.forkjoin_shared()
            + (self.fj.per_arg_cycles * nargs * batch as u64) as f64;

        let c = if spec {
            tile::specialized_level1_chunk_costs(&self.dma, &self.cluster, chunk)
        } else {
            tile::level1_chunk_costs(&self.dma, &self.cluster, chunk)
        };
        let per_chunk_compute = (c.dma.max(c.fpu) + c.dma).0 as f64;
        let mut per_member = 0.0;
        let mut i = 0;
        while i < n {
            let take = chunk.min(n - i);
            per_member += 2.0 * self.memcpy((take * 8) as u64) + per_chunk_compute;
            i += take;
        }
        fork + batch as f64 * per_member
    }

    /// Predicted cycles for the same level-1 batch on the host path.
    pub fn host_level1_cycles(&self, n: usize, batch: usize) -> f64 {
        batch.max(1) as f64 * self.host.level1_cycles(n, 2.0, false).0 as f64
    }

    // ------------------------------------------------------------------
    // Calibrated decisions
    // ------------------------------------------------------------------

    fn scaled_device(&self, op: CostOp, raw: f64) -> f64 {
        raw * self.calib.device_scale(op)
    }

    fn scaled_host(&self, op: CostOp, raw: f64) -> f64 {
        raw * self.calib.host_scale(op)
    }

    /// Does the device path win a single f64 GEMM of (m, n, k)?
    /// `warm_b` predicts B cache-resident on the target cluster (the
    /// cache-aware dispatch the affinity directory feeds).
    pub fn device_wins_gemm(&self, m: usize, n: usize, k: usize, warm_b: bool) -> bool {
        self.scaled_device(
            CostOp::Gemm,
            self.offload_gemm_cycles((m, n, k), 1, warm_b, true),
        ) < self.scaled_host(CostOp::Gemm, self.host_gemm_cycles((m, n, k), 1))
    }

    /// Does the device path win a single f64 GEMV of (m, n)?
    pub fn device_wins_gemv(&self, m: usize, n: usize) -> bool {
        self.scaled_device(CostOp::Gemv, self.offload_gemv_cycles((m, n), 1, true))
            < self.scaled_host(CostOp::Gemv, self.host_gemv_cycles((m, n), 1))
    }

    /// Does the device path win a single f64 level-1 call of length n?
    pub fn device_wins_level1(&self, n: usize, is_axpy: bool) -> bool {
        self.scaled_device(CostOp::Level1, self.offload_level1_cycles(n, 1, is_axpy))
            < self.scaled_host(CostOp::Level1, self.host_level1_cycles(n, 1))
    }

    /// The per-kernel correction for a specialized estimate: when the
    /// registry key is known its own EWMA scale applies (learned FPU
    /// rate of that compiled kernel), otherwise the estimate stands
    /// unscaled.
    fn kernel_scaled(&self, key: Option<u64>, raw: f64) -> f64 {
        raw * key.map(|k| self.calib.kernel_scale(k)).unwrap_or(1.0)
    }

    /// Does the device path win a single f64 GEMM through a
    /// registry-specialized walk?  `key` (when known) applies that
    /// kernel's learned scale — the specialized analogue of the
    /// family-level calibration.
    pub fn device_wins_gemm_spec(
        &self,
        m: usize,
        n: usize,
        k: usize,
        warm_b: bool,
        key: Option<u64>,
    ) -> bool {
        self.kernel_scaled(
            key,
            self.offload_gemm_cycles_spec((m, n, k), 1, warm_b, true),
        ) < self.scaled_host(CostOp::Gemm, self.host_gemm_cycles((m, n, k), 1))
    }

    /// Does the device path win a single f64 GEMV through a
    /// specialized walk?
    pub fn device_wins_gemv_spec(&self, m: usize, n: usize, key: Option<u64>) -> bool {
        self.kernel_scaled(key, self.offload_gemv_cycles_spec((m, n), 1, true))
            < self.scaled_host(CostOp::Gemv, self.host_gemv_cycles((m, n), 1))
    }

    /// Does the device path win a single f64 level-1 call through a
    /// specialized walk?
    pub fn device_wins_level1_spec(
        &self,
        n: usize,
        is_axpy: bool,
        key: Option<u64>,
    ) -> bool {
        self.kernel_scaled(key, self.offload_level1_cycles_spec(n, 1, is_axpy))
            < self.scaled_host(CostOp::Level1, self.host_level1_cycles(n, 1))
    }

    /// THE mode-to-path mapping, shared by every consumer that must
    /// agree with dispatch (the batcher's linger gate, the placement
    /// router's admission/footprints): forced modes answer directly,
    /// `Auto` is the cold model comparison for the serve-protocol op
    /// name ("gemm" dims (m, n, k), "gemv" (m, n, _), "axpy"/"dot"
    /// (n, _, _)).  Assumes the serving default of all kernels being
    /// device-enabled; the worker's own decision additionally applies
    /// `DispatchPolicy::device_kernels` and cache warmth — warmth only
    /// ever moves jobs host->device, so a cold-host answer here is
    /// conservative, never wrong-side for capacity.
    pub fn decides_device(
        &self,
        op: &str,
        dims: (usize, usize, usize),
        mode: DispatchMode,
    ) -> bool {
        match mode {
            DispatchMode::HostOnly => false,
            DispatchMode::DeviceOnly | DispatchMode::DeviceZeroCopy => true,
            DispatchMode::Auto => match op {
                "gemm" => self.device_wins_gemm(dims.0, dims.1, dims.2, false),
                "gemv" => self.device_wins_gemv(dims.0, dims.1),
                "axpy" => self.device_wins_level1(dims.0, true),
                "dot" => self.device_wins_level1(dims.0, false),
                _ => false,
            },
        }
    }

    // ------------------------------------------------------------------
    // Derived policy surfaces
    // ------------------------------------------------------------------

    /// Live calibrated crossovers per op (the smallest winning size),
    /// the specialized crossover reported next to the generic one.
    pub fn crossovers(&self) -> Crossovers {
        Crossovers {
            gemm_n: smallest(MAX_DIM, |n| self.device_wins_gemm(n, n, n, false)),
            gemm_warm_n: smallest(MAX_DIM, |n| self.device_wins_gemm(n, n, n, true)),
            gemv_n: smallest(MAX_DIM, |n| self.device_wins_gemv(n, n)),
            level1_n: smallest(MAX_LEVEL1_N, |n| self.device_wins_level1(n, true)),
            gemm_spec_n: smallest(MAX_DIM, |n| {
                self.device_wins_gemm_spec(n, n, n, false, None)
            }),
            gemv_spec_n: smallest(MAX_DIM, |n| self.device_wins_gemv_spec(n, n, None)),
            level1_spec_n: smallest(MAX_LEVEL1_N, |n| {
                self.device_wins_level1_spec(n, true, None)
            }),
        }
    }

    /// The batcher's amortization curve: with `batch_len` members
    /// already collected, the wall time worth waiting for ONE more is
    /// the marginal per-member fork-join saving `F/b - F/(b+1)` (the
    /// added member's own time is paid by that member either way).  Once
    /// this drops below the expected wait for the next arrival, lingering
    /// costs the queued members more latency than it saves — the batcher
    /// compares against its remaining window and stops.
    pub fn linger_allowance(&self, op: CostOp, batch_len: usize) -> Duration {
        let b = batch_len.max(1) as f64;
        let f_cycles = self.scaled_device(op, self.forkjoin_shared());
        let secs = f_cycles / (b * (b + 1.0)) / self.freq_hz as f64;
        Duration::from_secs_f64(secs)
    }

    /// Software-pipelining overlap credit: how many of this batch's
    /// map-in cycles hide under the previous batch's compute window
    /// (the data path double-buffers, so the hideable share is the
    /// smaller of the two regions).
    pub fn overlap_credit(&self, map_in_cycles: u64, prev_compute_cycles: u64) -> u64 {
        map_in_cycles.min(prev_compute_cycles)
    }

    /// Staged device-DRAM footprint of an f64 GEMM (what the placement
    /// router sizes lanes and steals against).
    pub fn gemm_staged_bytes(&self, dims: (usize, usize, usize)) -> u64 {
        gemm_staged_bytes_tiled(self.tile, dims, 8)
    }

    /// Staged device-DRAM footprint of an f64 GEMV.
    pub fn gemv_staged_bytes(&self, dims: (usize, usize)) -> u64 {
        gemv_staged_bytes_tiled(self.tile, dims, 8)
    }

    // ------------------------------------------------------------------
    // Feedback
    // ------------------------------------------------------------------

    /// Fold one observed batch timing into the calibration (no-op unless
    /// `[cost] calibrate` is on).  `op` is the serve-protocol name with
    /// dims as in [`CostModel::decides_device`]; `observed_cycles` is
    /// the batch's total virtual time on its path; `warm_b` must be the
    /// warmth the batch actually staged with (a warm batch compared
    /// against the cold prediction would read as "device faster than
    /// predicted" and floor-bias the scale).  Residual bias: in a
    /// multi-member shared-B batch the first member is cold and the rest
    /// hit — between the two predictions; the clamps bound it.
    pub fn observe(
        &self,
        op: &str,
        dims: (usize, usize, usize),
        batch: usize,
        observed_cycles: u64,
        host_path: bool,
        warm_b: bool,
    ) {
        if !self.knobs.calibrate || observed_cycles == 0 {
            return;
        }
        let Some(cop) = CostOp::from_name(op) else {
            return;
        };
        let (device_pred, host_pred) = match op {
            "gemm" => (
                self.offload_gemm_cycles((dims.0, dims.1, dims.2), batch, warm_b, true),
                self.host_gemm_cycles((dims.0, dims.1, dims.2), batch),
            ),
            "gemv" => (
                self.offload_gemv_cycles((dims.0, dims.1), batch, true),
                self.host_gemv_cycles((dims.0, dims.1), batch),
            ),
            // axpy and dot share the Level1 scale but predict with their
            // own per-arg marshalling counts
            "axpy" | "dot" => (
                self.offload_level1_cycles(dims.0, batch, op == "axpy"),
                self.host_level1_cycles(dims.0, batch),
            ),
            _ => return,
        };
        if host_path {
            self.calib
                .observe_host(cop, host_pred, observed_cycles as f64, &self.knobs);
        } else {
            self.calib
                .observe_device(cop, device_pred, observed_cycles as f64, &self.knobs);
        }
    }

    /// Chain-launch feedback: the twin of [`CostModel::observe`] for
    /// chained executions, which have no single `(m, n, k)` and were
    /// previously dropped by `observe`'s op-name mapping (so chained
    /// traffic never calibrated anything).  A chain is GEMM traffic with
    /// its interior copies elided — [`CostModel::device_wins_chain`]
    /// already compares chain predictions under the *GEMM* scales, so the
    /// observed timing folds into those same scales and the crossover it
    /// decides moves with the feedback.
    pub fn observe_chain(
        &self,
        m: usize,
        dims: &[usize],
        observed_cycles: u64,
        host_path: bool,
    ) {
        if !self.knobs.calibrate || observed_cycles == 0 || dims.len() < 2 {
            return;
        }
        if host_path {
            let pred = self.host_chain_cycles(m, dims);
            self.calib
                .observe_host(CostOp::Gemm, pred, observed_cycles as f64, &self.knobs);
        } else {
            let pred = self.offload_chain_cycles(m, dims);
            self.calib
                .observe_device(CostOp::Gemm, pred, observed_cycles as f64, &self.knobs);
        }
    }

    /// Specialized-launch feedback: fold one observed fast-path batch
    /// timing into that kernel's own EWMA scale (the per-kernel FPU
    /// rate).  Dims follow the [`CostModel::observe`] convention; the
    /// prediction is the specialized estimate, so the ratio measures
    /// how the *compiled* walk really runs, not the family average.
    pub fn observe_kernel(
        &self,
        key: u64,
        op: &str,
        dims: (usize, usize, usize),
        batch: usize,
        observed_cycles: u64,
    ) {
        if !self.knobs.calibrate || observed_cycles == 0 {
            return;
        }
        let pred = match op {
            "gemm" => {
                self.offload_gemm_cycles_spec((dims.0, dims.1, dims.2), batch, false, true)
            }
            "gemv" => self.offload_gemv_cycles_spec((dims.0, dims.1), batch, true),
            "axpy" | "dot" => {
                self.offload_level1_cycles_spec(dims.0, batch, op == "axpy")
            }
            _ => return,
        };
        self.calib
            .observe_kernel(key, pred, observed_cycles as f64, &self.knobs);
    }
}

/// The calibration family a DAG node's timings fold into.
fn dag_family(op: DagOp) -> CostOp {
    match op {
        DagOp::Gemm => CostOp::Gemm,
        DagOp::Gemv => CostOp::Gemv,
        DagOp::Axpy | DagOp::Dot => CostOp::Level1,
    }
}

/// Smallest `n in 1..=hi` satisfying `p` (binary search; the win
/// predicate is monotone in problem size because the device advantage
/// grows with FLOPs while the fork-join stays fixed).
fn smallest(hi: usize, p: impl Fn(usize) -> bool) -> Option<usize> {
    if !p(hi) {
        return None;
    }
    let (mut lo, mut hi) = (1usize, hi);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if p(mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Some(lo)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel::from_platform(&PlatformConfig::default(), (64, 64, 64), 4096)
    }

    fn calibrating_model() -> CostModel {
        let mut cfg = PlatformConfig::default();
        cfg.cost.calibrate = true;
        CostModel::from_platform(&cfg, (64, 64, 64), 4096)
    }

    #[test]
    fn gemm_crossover_sits_in_the_figure3_band() {
        let m = model();
        let x = m.crossovers();
        let n = x.gemm_n.expect("gemm must cross over");
        // the paper's Figure 3: offload loses at 64, wins at 128
        assert!(n > 64 && n <= 128, "cold gemm crossover n={n}");
        assert!(!m.device_wins_gemm(64, 64, 64, false));
        assert!(m.device_wins_gemm(128, 128, 128, false));
        // tiny problems are dominated by the fixed fork-join
        assert!(!m.device_wins_gemm(16, 16, 16, false));
    }

    #[test]
    fn warm_b_moves_the_crossover_below_cold_when_cache_is_on() {
        let mut cfg = PlatformConfig::default();
        cfg.sched.cache.cache_frac = 0.4; // cache on => elisions modelled
        let m = CostModel::from_platform(&cfg, (64, 64, 64), 4096);
        let x = m.crossovers();
        let (cold, warm) = (x.gemm_n.unwrap(), x.gemm_warm_n.unwrap());
        assert!(
            warm < cold,
            "warm crossover {warm} must undercut cold {cold}"
        );
        // with the cache off, warmth cannot be exploited: same estimate
        let off = model();
        assert_eq!(
            off.offload_gemm_cycles((128, 128, 128), 1, true, true),
            off.offload_gemm_cycles((128, 128, 128), 1, false, true),
        );
    }

    #[test]
    fn gemv_and_level1_never_win_cold_in_copy_mode() {
        // the partition copy of A (27.8 cycles/elem) outweighs the host's
        // 5 cycles/elem at every size — the old static thresholds
        // (512x512, 1M) were wrong about this, which is the point of
        // deriving dispatch from the model
        let m = model();
        let x = m.crossovers();
        assert_eq!(x.gemv_n, None);
        assert_eq!(x.level1_n, None);
        assert!(!m.device_wins_gemv(2048, 2048));
        assert!(!m.device_wins_level1(1 << 20, true));
    }

    #[test]
    fn batching_amortizes_the_fixed_cost() {
        let m = model();
        let one = m.offload_gemm_cycles((64, 64, 64), 1, false, true);
        let eight = m.offload_gemm_cycles((64, 64, 64), 8, false, true);
        // 8 members cost far less than 8 launches
        assert!(eight < 8.0 * one * 0.7, "batch 8: {eight} vs 8x{one}");
        // the per-member marginal is below the single-call cost by ~the
        // shared fork-join
        let marginal = eight - m.offload_gemm_cycles((64, 64, 64), 7, false, true);
        assert!(marginal < one - 1_000_000.0);
    }

    #[test]
    fn calibration_moves_the_crossover_toward_injected_truth() {
        let m = calibrating_model();
        let base = m.crossovers().gemm_n.unwrap();

        // inject a device that is really 3x slower than the analytical
        // estimate: the crossover must climb toward (and past) the truth
        for n in [64usize, 96, 128] {
            let pred = m.offload_gemm_cycles((n, n, n), 1, false, true);
            for _ in 0..64 {
                m.observe("gemm", (n, n, n), 1, (pred * 3.0) as u64, false, false);
            }
        }
        let slow = m.crossovers().gemm_n.unwrap();
        assert!(slow > base, "3x-slow device: crossover {base} -> {slow}");

        // now a device 4x faster than estimated: crossover must drop
        let m2 = calibrating_model();
        for n in [64usize, 96, 128] {
            let pred = m2.offload_gemm_cycles((n, n, n), 1, false, true);
            for _ in 0..64 {
                m2.observe("gemm", (n, n, n), 1, (pred * 0.25) as u64, false, false);
            }
        }
        let fast = m2.crossovers().gemm_n.unwrap();
        assert!(fast < base, "4x-fast device: crossover {base} -> {fast}");
    }

    #[test]
    fn specialized_walk_undercuts_generic_and_moves_the_crossover_down() {
        let m = model();
        // same fork-join + map traffic, leaner walk: strictly cheaper
        for n in [64usize, 128, 256] {
            assert!(
                m.offload_gemm_cycles_spec((n, n, n), 1, false, true)
                    < m.offload_gemm_cycles((n, n, n), 1, false, true),
                "spec gemm estimate must undercut generic at n={n}"
            );
        }
        // level-2/level-1 steps are DMA-bound: a leaner burst can only
        // help when the FPU was the binding side, so never regress
        assert!(
            m.offload_gemv_cycles_spec((256, 256), 1, true)
                <= m.offload_gemv_cycles((256, 256), 1, true)
        );
        assert!(
            m.offload_level1_cycles_spec(1 << 16, 1, true)
                <= m.offload_level1_cycles(1 << 16, 1, true)
        );
        // the dual crossover lines: specialized at or below generic,
        // exactly like the cache-aware warm path sits below cold
        let x = m.crossovers();
        let (cold, spec) = (x.gemm_n.unwrap(), x.gemm_spec_n.unwrap());
        assert!(spec <= cold, "spec crossover {spec} must not exceed cold {cold}");
        // gemv/level-1 stay copy-bound: specializing the burst cannot
        // rescue them in copy mode
        assert_eq!(x.gemv_spec_n, None);
        assert_eq!(x.level1_spec_n, None);
    }

    #[test]
    fn per_kernel_feedback_flips_only_that_kernels_decision() {
        let m = calibrating_model();
        let key = 0xfeed;
        // at the smallest winning size the margin is minimal, so a
        // kernel observed 4x slower than its estimate must flip there
        let n = m.crossovers().gemm_spec_n.expect("spec gemm crosses over");
        assert!(m.device_wins_gemm_spec(n, n, n, false, Some(key)));
        let pred = m.offload_gemm_cycles_spec((n, n, n), 1, false, true);
        for _ in 0..64 {
            m.observe_kernel(key, "gemm", (n, n, n), 1, (pred * 4.0) as u64);
        }
        assert!(!m.device_wins_gemm_spec(n, n, n, false, Some(key)));
        // ...while other kernels and the family scales are untouched
        assert!(m.device_wins_gemm_spec(n, n, n, false, Some(0xbeef)));
        assert!(m.device_wins_gemm_spec(n, n, n, false, None));
        assert_eq!(m.calibration().device_scale(CostOp::Gemm), 1.0);

        // inert with calibration off or degenerate observations
        let off = model();
        off.observe_kernel(key, "gemm", (128, 128, 128), 1, u64::MAX / 2);
        assert_eq!(off.calibration().kernel_scale(key), 1.0);
        m.observe_kernel(0x77, "fence", (128, 128, 128), 1, 1000);
        m.observe_kernel(0x77, "gemm", (128, 128, 128), 1, 0);
        assert_eq!(m.calibration().kernel_scale(0x77), 1.0);
    }

    #[test]
    fn decides_device_is_the_shared_mode_mapping() {
        let m = model();
        // forced modes answer without consulting the estimates
        assert!(!m.decides_device("gemm", (4096, 4096, 4096), DispatchMode::HostOnly));
        assert!(m.decides_device("gemm", (2, 2, 2), DispatchMode::DeviceOnly));
        assert!(m.decides_device("gemv", (2, 2, 0), DispatchMode::DeviceZeroCopy));
        // Auto matches the per-op win predicates (incl. the axpy/dot split)
        assert!(m.decides_device("gemm", (128, 128, 128), DispatchMode::Auto));
        assert!(!m.decides_device("gemm", (64, 64, 64), DispatchMode::Auto));
        assert!(!m.decides_device("gemv", (2048, 2048, 0), DispatchMode::Auto));
        assert!(!m.decides_device("axpy", (1 << 20, 0, 0), DispatchMode::Auto));
        assert!(!m.decides_device("dot", (1 << 20, 0, 0), DispatchMode::Auto));
        assert!(!m.decides_device("fence", (0, 0, 0), DispatchMode::Auto));
        // dot predicts 2 marshalled args per member, axpy 3
        assert!(
            m.offload_level1_cycles(4096, 4, false)
                < m.offload_level1_cycles(4096, 4, true)
        );
    }

    #[test]
    fn observe_is_inert_with_calibration_off() {
        let m = model(); // default: calibrate = false
        let before = m.crossovers();
        for _ in 0..64 {
            m.observe("gemm", (128, 128, 128), 1, u64::MAX / 2, false, false);
            m.observe("gemv", (256, 256, 0), 1, 1, true, false);
        }
        assert_eq!(m.crossovers(), before);
        assert_eq!(m.calibration().device_scale(CostOp::Gemm), 1.0);
    }

    #[test]
    fn clones_share_calibration() {
        let a = calibrating_model();
        let b = a.clone();
        let pred = a.offload_gemm_cycles((128, 128, 128), 1, false, true);
        for _ in 0..64 {
            a.observe("gemm", (128, 128, 128), 1, (pred * 2.0) as u64, false, false);
        }
        assert!(
            (b.calibration().device_scale(CostOp::Gemm) - 2.0).abs() < 0.1,
            "clone must see the shared scales"
        );
    }

    #[test]
    fn linger_allowance_decays_quadratically() {
        let m = model();
        let a1 = m.linger_allowance(CostOp::Gemm, 1);
        let a2 = m.linger_allowance(CostOp::Gemm, 2);
        let a4 = m.linger_allowance(CostOp::Gemm, 4);
        assert!(a1 > a2 && a2 > a4);
        // F = 1.21M cycles at 50 MHz => F/2 ~ 12 ms for the second member
        assert!(a1 > Duration::from_millis(5) && a1 < Duration::from_millis(30));
        // marginal saving at b=4 is F/20 ~ 1.2 ms
        assert!(a4 < Duration::from_millis(3));
    }

    #[test]
    fn chain_elision_moves_links_below_the_crossover_onto_the_device() {
        let m = model();
        // each n=64 link alone loses to the host (below the Figure-3
        // crossover)...
        assert!(!m.device_wins_gemm(64, 64, 64, false));
        assert!(!m.device_wins_chain(64, &[64, 64]), "one link = one gemm-ish cost");
        // ...but a 3-link chain pays ONE fork-join and zero interior
        // copies, so the device wins where per-op execution never would
        assert!(m.device_wins_chain(64, &[64, 64, 64, 64]));
        assert!(m.decides_device_chain(64, &[64, 64, 64, 64], DispatchMode::Auto));
        assert!(!m.decides_device_chain(64, &[64, 64, 64, 64], DispatchMode::HostOnly));
        assert!(m.decides_device_chain(64, &[16, 16], DispatchMode::DeviceOnly));

        // the chain estimate undercuts L separate offloads by ~(L-1)
        // fork-joins plus the interior copies
        let chain = m.offload_chain_cycles(64, &[64, 64, 64, 64]);
        let three = 3.0 * m.offload_gemm_cycles((64, 64, 64), 1, false, true);
        assert!(
            chain < three - 2.0 * m.forkjoin_shared(),
            "chain {chain} vs 3 offloads {three}"
        );
        // degenerate chains never claim the device
        assert!(!m.device_wins_chain(64, &[64]));
        assert_eq!(m.offload_chain_cycles(64, &[64]), 0.0);
    }

    #[test]
    fn observe_chain_calibrates_the_gemm_scales() {
        let m = calibrating_model();
        let dims = [64usize, 64, 64, 64];
        assert!(m.device_wins_chain(64, &dims), "precondition: device wins cold");
        // a device really 3x slower than the chain prediction: the GEMM
        // device scale climbs and the chain decision flips to host
        let pred = m.offload_chain_cycles(64, &dims);
        for _ in 0..64 {
            m.observe_chain(64, &dims, (pred * 3.0) as u64, false);
        }
        assert!(
            m.calibration().device_scale(CostOp::Gemm) > 2.0,
            "chain feedback must reach the shared GEMM scale"
        );
        assert!(!m.device_wins_chain(64, &dims), "3x-slow device loses the chain");

        // guards: zero observation, degenerate dims, calibration off
        let frozen = m.calibration().device_scale(CostOp::Gemm);
        m.observe_chain(64, &dims, 0, false);
        m.observe_chain(64, &[64], u64::MAX / 2, false);
        assert_eq!(m.calibration().device_scale(CostOp::Gemm), frozen);
        let off = model();
        off.observe_chain(64, &dims, u64::MAX / 2, false);
        assert_eq!(off.calibration().device_scale(CostOp::Gemm), 1.0);

        // host-path chain feedback lands on the host scale
        let mh = calibrating_model();
        let host_pred = mh.host_chain_cycles(64, &dims);
        for _ in 0..64 {
            mh.observe_chain(64, &dims, (host_pred * 2.0) as u64, true);
        }
        assert!(
            (mh.calibration().host_scale(CostOp::Gemm) - 2.0).abs() < 0.1,
            "host-path chain feedback calibrates the host scale"
        );
    }

    #[test]
    fn linear_dag_estimates_are_the_chain_estimates() {
        use crate::dag::linear_gemm_shape;
        let m = model();
        for dims in [&[64usize, 64][..], &[64, 64, 64, 64], &[512, 128, 64]] {
            let shape = linear_gemm_shape(64, dims);
            // charge for charge: a linear gemm dag IS the chain
            assert_eq!(
                m.offload_dag_cycles(&shape),
                m.offload_chain_cycles(64, dims),
                "device estimate for dims {dims:?}"
            );
            assert_eq!(
                m.host_dag_cycles(&shape),
                m.host_chain_cycles(64, dims),
                "host estimate for dims {dims:?}"
            );
            assert_eq!(
                m.device_wins_dag(&shape),
                m.device_wins_chain(64, dims),
                "decision for dims {dims:?}"
            );
        }
        // mode mapping mirrors the chain's
        let shape = linear_gemm_shape(64, &[64, 64, 64, 64]);
        assert!(m.decides_device_dag(&shape, DispatchMode::Auto));
        assert!(!m.decides_device_dag(&shape, DispatchMode::HostOnly));
        assert!(m.decides_device_dag(
            &linear_gemm_shape(16, &[16, 16]),
            DispatchMode::DeviceOnly
        ));
        // degenerate
        assert!(!m.device_wins_dag(&DagShape { m: 8, d0: 8, nodes: vec![] }));
        assert_eq!(
            m.offload_dag_cycles(&DagShape { m: 8, d0: 8, nodes: vec![] }),
            0.0
        );
    }

    #[test]
    fn fanout_dag_undercuts_two_separate_chain_launches() {
        use crate::dag::DagNodeShape;
        let m = model();
        // a two-head MLP: shared 64->256 trunk feeding two 256->64 heads
        let two_head = DagShape {
            m: 64,
            d0: 64,
            nodes: vec![
                DagNodeShape {
                    op: DagOp::Gemm,
                    src: None,
                    src2: None,
                    n: 256,
                    bias: false,
                    relu: false,
                },
                DagNodeShape {
                    op: DagOp::Gemm,
                    src: Some(0),
                    src2: None,
                    n: 64,
                    bias: false,
                    relu: false,
                },
                DagNodeShape {
                    op: DagOp::Gemm,
                    src: Some(0),
                    src2: None,
                    n: 64,
                    bias: false,
                    relu: false,
                },
            ],
        };
        // against two chained submissions the dag stages the trunk once
        // and pays one fork-join instead of two
        let dag = m.offload_dag_cycles(&two_head);
        let chains = 2.0 * m.offload_chain_cycles(64, &[64, 256, 64]);
        assert!(
            dag < chains - m.forkjoin_shared(),
            "dag {dag} vs two chains {chains}"
        );
    }

    #[test]
    fn per_node_attribution_calibrates_each_family_independently() {
        use crate::dag::DagNodeShape;
        let m = calibrating_model();
        // a mixed dag: two gemm heads off x, an axpy fan-in, a gemv sink
        let node = |op, src, src2, n| DagNodeShape {
            op,
            src,
            src2,
            n,
            bias: false,
            relu: false,
        };
        let shape = DagShape {
            m: 64,
            d0: 64,
            nodes: vec![
                node(DagOp::Gemm, None, None, 64),
                node(DagOp::Gemm, None, None, 64),
                node(DagOp::Axpy, Some(0), Some(1), 0),
                node(DagOp::Gemv, Some(2), None, 0),
            ],
        };
        // the device really runs gemm walks 3x, gemv 2x, level-1 1.5x
        // slower than predicted: ONE launch's per-node deltas calibrate
        // all three families, each to its own truth
        let factor = |op: DagOp| match op {
            DagOp::Gemm => 3.0,
            DagOp::Gemv => 2.0,
            DagOp::Axpy | DagOp::Dot => 1.5,
        };
        let cycles: Vec<u64> = (0..shape.nodes.len())
            .map(|i| {
                (m.dag_node_walk_cycles(&shape, i) * factor(shape.nodes[i].op))
                    as u64
            })
            .collect();
        for _ in 0..64 {
            m.observe_dag_nodes(&shape, &cycles);
        }
        let c = m.calibration();
        assert!((c.device_scale(CostOp::Gemm) - 3.0).abs() < 0.15);
        assert!((c.device_scale(CostOp::Gemv) - 2.0).abs() < 0.15);
        assert!((c.device_scale(CostOp::Level1) - 1.5).abs() < 0.15);
        // host scales are untouched by device-path attribution
        assert_eq!(c.host_scale(CostOp::Gemm), 1.0);

        // guards: a length mismatch or calibration off stays inert
        let frozen = c.device_scale(CostOp::Gemm);
        m.observe_dag_nodes(&shape, &cycles[..2]);
        assert_eq!(m.calibration().device_scale(CostOp::Gemm), frozen);
        let off = model();
        off.observe_dag_nodes(&shape, &cycles);
        assert_eq!(off.calibration().device_scale(CostOp::Gemm), 1.0);

        // host-path whole-launch feedback reaches every family present
        let mh = calibrating_model();
        let pred = mh.host_dag_cycles(&shape);
        for _ in 0..64 {
            mh.observe_dag_host(&shape, (pred * 2.0) as u64);
        }
        for fam in [CostOp::Gemm, CostOp::Gemv, CostOp::Level1] {
            let s = mh.calibration().host_scale(fam);
            assert!((s - 2.0).abs() < 0.1, "host {fam:?} scale {s}");
        }
    }

    #[test]
    fn dag_footprint_matches_the_tile_formula() {
        use crate::dag::linear_gemm_shape;
        let m = model();
        let shape = linear_gemm_shape(128, &[256, 128, 64]);
        assert_eq!(
            m.dag_staged_bytes(&shape),
            crate::cost::tile::dag_staged_bytes_tiled((64, 64, 64), &shape, 8)
        );
    }

    #[test]
    fn chain_footprint_matches_the_tile_formula() {
        let m = model();
        assert_eq!(
            m.chain_staged_bytes(128, &[256, 128, 64]),
            crate::cost::tile::chain_staged_bytes_tiled(
                (64, 64, 64),
                128,
                &[256, 128, 64],
                8
            )
        );
    }

    #[test]
    fn staged_footprints_match_the_tile_formulas() {
        let m = model();
        assert_eq!(
            m.gemm_staged_bytes((1600, 1600, 1600)),
            gemm_staged_bytes_tiled((64, 64, 64), (1600, 1600, 1600), 8)
        );
        assert_eq!(
            m.gemv_staged_bytes((2048, 2048)),
            gemv_staged_bytes_tiled((64, 64, 64), (2048, 2048), 8)
        );
        assert_eq!(m.overlap_credit(100, 60), 60);
        assert_eq!(m.overlap_credit(40, 60), 40);
    }
}
