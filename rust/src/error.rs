//! Crate-wide error type.
//!
//! Everything user-facing goes through [`Error`]; internal invariant
//! violations panic (they indicate bugs, not recoverable conditions).

use thiserror::Error;

/// Errors surfaced by the hero-blas stack.
#[derive(Error, Debug)]
pub enum Error {
    /// Shape/argument mismatch at the BLAS or ndarray layer.
    #[error("shape error: {0}")]
    Shape(String),

    /// Device-DRAM or L2-SPM allocation failure.
    #[error("allocator: {0}")]
    Alloc(String),

    /// Device lifecycle misuse (e.g. launch before boot).
    #[error("device: {0}")]
    Device(String),

    /// OpenMP-style offload/data-mapping failure.
    #[error("offload: {0}")]
    Offload(String),

    /// Artifact registry / PJRT failure.
    #[error("runtime: {0}")]
    Runtime(String),

    /// Platform/workload configuration problem.
    #[error("config: {0}")]
    Config(String),

    /// Underlying XLA error.
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    /// I/O while loading configs or artifacts.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for shape errors (the most common construction site).
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
}
