//! PJRT artifact registry against the real `make artifacts` output:
//! load, compile, execute, and compare against the Rust host kernels.

mod common;

use common::{artifacts_dir, max_abs_diff};
use hero_blas::blas::host;
use hero_blas::runtime::literal::{lit_1d, lit_2d};
use hero_blas::runtime::ArtifactRegistry;
use hero_blas::util::rng::Rng;

#[test]
fn manifest_has_expected_catalog() {
    let reg = ArtifactRegistry::open(&artifacts_dir()).unwrap();
    let man = reg.manifest();
    assert_eq!((man.tile_m, man.tile_n, man.tile_k), (64, 64, 64));
    for name in [
        "gemm_tile_accum_f64",
        "gemm_tile_accum_f32",
        "gemm_f64_n128",
        "gemm_f32_n128",
        "gemv_f64_n128",
        "axpy_f64_n1024",
        "dot_f64_n4096",
    ] {
        assert!(man.entry(name).is_ok(), "missing artifact {name}");
    }
}

#[test]
fn fixed_size_gemm_artifact_matches_host_kernel() {
    let mut reg = ArtifactRegistry::open(&artifacts_dir()).unwrap();
    let mut rng = Rng::new(77);
    let n = 128;
    let a = rng.normal_vec(n * n);
    let b = rng.normal_vec(n * n);
    let c = rng.normal_vec(n * n);
    let out = reg
        .exec(
            "gemm_f64_n128",
            &[
                lit_2d(&a, n, n).unwrap(),
                lit_2d(&b, n, n).unwrap(),
                lit_2d(&c, n, n).unwrap(),
                lit_1d(&[1.5f64]),
                lit_1d(&[-0.5f64]),
            ],
        )
        .unwrap();
    let got = out.to_vec::<f64>().unwrap();
    let mut want = c.clone();
    host::gemm(n, n, n, 1.5, &a, &b, -0.5, &mut want);
    let err = max_abs_diff(&got, &want);
    assert!(err < 1e-9, "artifact vs host kernel err {err}");
}

#[test]
fn tile_accum_artifact_composes_to_full_gemm() {
    // composing the per-tile artifact over rust's own K loop must equal
    // the one-shot fixed-size artifact — the two independent lowerings
    // cross-validate each other.
    let mut reg = ArtifactRegistry::open(&artifacts_dir()).unwrap();
    let mut rng = Rng::new(78);
    let n = 128; // 2x2x2 tiles of 64
    let a = rng.normal_vec(n * n);
    let b = rng.normal_vec(n * n);

    let tile = 64;
    let g = n / tile;
    let mut c = vec![0.0f64; n * n];
    for i in 0..g {
        for j in 0..g {
            let mut acc = vec![0.0f64; tile * tile];
            for kk in 0..g {
                let mut at = vec![0.0f64; tile * tile];
                let mut bt = vec![0.0f64; tile * tile];
                for r in 0..tile {
                    for cc in 0..tile {
                        at[r * tile + cc] = a[(i * tile + r) * n + kk * tile + cc];
                        bt[r * tile + cc] = b[(kk * tile + r) * n + j * tile + cc];
                    }
                }
                let out = reg
                    .exec(
                        "gemm_tile_accum_f64",
                        &[
                            lit_2d(&acc, tile, tile).unwrap(),
                            lit_2d(&at, tile, tile).unwrap(),
                            lit_2d(&bt, tile, tile).unwrap(),
                        ],
                    )
                    .unwrap();
                acc = out.to_vec::<f64>().unwrap();
            }
            for r in 0..tile {
                for cc in 0..tile {
                    c[(i * tile + r) * n + j * tile + cc] = acc[r * tile + cc];
                }
            }
        }
    }

    let zero = vec![0.0f64; n * n];
    let one_shot = reg
        .exec(
            "gemm_f64_n128",
            &[
                lit_2d(&a, n, n).unwrap(),
                lit_2d(&b, n, n).unwrap(),
                lit_2d(&zero, n, n).unwrap(),
                lit_1d(&[1.0f64]),
                lit_1d(&[0.0f64]),
            ],
        )
        .unwrap()
        .to_vec::<f64>()
        .unwrap();
    let err = max_abs_diff(&c, &one_shot);
    assert!(err < 1e-10, "tile composition vs one-shot artifact: {err}");
}

#[test]
fn gemv_and_level1_artifacts_match_host() {
    let mut reg = ArtifactRegistry::open(&artifacts_dir()).unwrap();
    let mut rng = Rng::new(79);

    let n = 128;
    let a = rng.normal_vec(n * n);
    let x = rng.normal_vec(n);
    let y = rng.normal_vec(n);
    let out = reg
        .exec(
            "gemv_f64_n128",
            &[
                lit_2d(&a, n, n).unwrap(),
                lit_1d(&x),
                lit_1d(&y),
                lit_1d(&[2.0f64]),
                lit_1d(&[0.5f64]),
            ],
        )
        .unwrap()
        .to_vec::<f64>()
        .unwrap();
    let mut want = y.clone();
    host::gemv(n, n, 2.0, &a, &x, 0.5, &mut want);
    assert!(max_abs_diff(&out, &want) < 1e-10);

    let m = 1024;
    let xv = rng.normal_vec(m);
    let yv = rng.normal_vec(m);
    let axpy_out = reg
        .exec("axpy_f64_n1024", &[lit_1d(&[3.0f64]), lit_1d(&xv), lit_1d(&yv)])
        .unwrap()
        .to_vec::<f64>()
        .unwrap();
    let mut want = yv.clone();
    host::axpy(3.0, &xv, &mut want);
    assert!(max_abs_diff(&axpy_out, &want) < 1e-12);

    let dot_out = reg
        .exec("dot_f64_n1024", &[lit_1d(&xv), lit_1d(&yv)])
        .unwrap()
        .to_vec::<f64>()
        .unwrap();
    assert!((dot_out[0] - host::dot(&xv, &yv)).abs() < 1e-9);
}

#[test]
fn warm_up_compiles_everything_once() {
    let mut reg = ArtifactRegistry::open(&artifacts_dir()).unwrap();
    let total = reg.manifest().entries.len();
    reg.warm_up().unwrap();
    assert_eq!(reg.resident(), total);
    let compiles = reg.stats().compiles;
    assert_eq!(compiles as usize, total);
    // second warm-up is a no-op
    reg.warm_up().unwrap();
    assert_eq!(reg.stats().compiles, compiles);
}

#[test]
fn bad_arg_count_rejected() {
    let mut reg = ArtifactRegistry::open(&artifacts_dir()).unwrap();
    let err = match reg.exec("dot_f64_n1024", &[lit_1d(&[0.0f64; 1024])]) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("arg-count mismatch must be rejected"),
    };
    assert!(err.contains("args"), "{err}");
}

#[test]
fn unknown_artifact_rejected() {
    let mut reg = ArtifactRegistry::open(&artifacts_dir()).unwrap();
    assert!(reg.exec("does_not_exist", &[]).is_err());
}
