//! The request loop end-to-end: spawn the server on an ephemeral port,
//! drive it over TCP, check responses and region accounting.

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use common::artifacts_dir;
use hero_blas::config::PlatformConfig;
use hero_blas::util::json_lite::Json;

fn request(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Json {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response '{resp}': {e}"))
}

#[test]
fn serve_gemm_requests_end_to_end() {
    let dir = artifacts_dir();
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        hero_blas::serve::serve(PlatformConfig::default(), &dir, 0, Some(tx))
    });
    // the pool warms one PJRT registry per cluster before listening
    let port = rx.recv_timeout(std::time::Duration::from_secs(300)).unwrap();

    let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // ping
    let pong = request(&mut stream, &mut reader, r#"{"op": "ping"}"#);
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

    // offloaded gemm: regions must be populated and sum to total
    let r = request(
        &mut stream,
        &mut reader,
        r#"{"op": "gemm", "n": 64, "mode": "device_only"}"#,
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    let get = |k: &str| r.get(k).and_then(|v| v.as_f64()).unwrap();
    assert!(get("data_copy_ms") > 0.0);
    assert!(get("fork_join_ms") > 0.0);
    assert!(get("compute_ms") > 0.0);
    let sum = get("data_copy_ms") + get("fork_join_ms") + get("compute_ms")
        + get("host_compute_ms");
    assert!((sum - get("total_ms")).abs() < 1e-6);
    // scheduler provenance: which cluster served it, how it batched
    assert!(get("cluster") < 64.0);
    assert!(get("batch_size") >= 1.0);
    assert!(get("queue_ms") >= 0.0);

    // identical requests are deterministic (stable default seed)
    let r2 = request(
        &mut stream,
        &mut reader,
        r#"{"op": "gemm", "n": 64, "mode": "device_only"}"#,
    );
    assert_eq!(
        r.get("checksum").and_then(|v| v.as_f64()).unwrap(),
        r2.get("checksum").and_then(|v| v.as_f64()).unwrap(),
    );

    // host-mode gemm: only host_compute
    let r = request(
        &mut stream,
        &mut reader,
        r#"{"op": "gemm", "n": 32, "mode": "host_only"}"#,
    );
    assert!(r.get("host_compute_ms").and_then(|v| v.as_f64()).unwrap() > 0.0);
    assert_eq!(r.get("data_copy_ms").and_then(|v| v.as_f64()).unwrap(), 0.0);

    // unknown op: ok:false with an error naming the op, connection stays up
    let r = request(&mut stream, &mut reader, r#"{"op": "bogus"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert!(
        r.get("error").and_then(|v| v.as_str()).unwrap().contains("bogus"),
        "{r:?}"
    );

    // malformed JSON: explicit error line, not a dropped connection
    let r = request(&mut stream, &mut reader, "not json at all");
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert!(
        r.get("error").and_then(|v| v.as_str()).unwrap().contains("bad json"),
        "{r:?}"
    );
    // ...and the same connection keeps serving afterwards
    let pong = request(&mut stream, &mut reader, r#"{"op": "ping"}"#);
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

    // out-of-range n, bad mode, bad priority: all explicit errors
    let r = request(&mut stream, &mut reader, r#"{"op": "gemm", "n": 99999}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    let r = request(
        &mut stream,
        &mut reader,
        r#"{"op": "gemm", "mode": "warp_drive"}"#,
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    let r = request(
        &mut stream,
        &mut reader,
        r#"{"op": "gemm", "priority": "urgent"}"#,
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));

    // gemv over the wire: same response shape, op/m echoed
    let r = request(
        &mut stream,
        &mut reader,
        r#"{"op": "gemv", "m": 64, "n": 64, "mode": "device_only"}"#,
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    assert_eq!(r.get("op").and_then(|v| v.as_str()), Some("gemv"));
    assert_eq!(r.get("m").and_then(|v| v.as_u64()), Some(64));
    assert!(r.get("fork_join_ms").and_then(|v| v.as_f64()).unwrap() > 0.0);
    // deterministic default seed, like gemm
    let r2 = request(
        &mut stream,
        &mut reader,
        r#"{"op": "gemv", "m": 64, "n": 64, "mode": "device_only"}"#,
    );
    assert_eq!(
        r.get("checksum").and_then(|v| v.as_f64()).unwrap(),
        r2.get("checksum").and_then(|v| v.as_f64()).unwrap(),
    );

    // trace: true returns the span breakdown; the five named stages sum
    // exactly to the reported end-to-end latency
    let r = request(
        &mut stream,
        &mut reader,
        r#"{"op": "gemm", "n": 64, "mode": "device_only", "trace": true, "req_id": "t-1"}"#,
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    assert_eq!(r.get("req_id").and_then(|v| v.as_str()), Some("t-1"));
    let latency = r.get("latency_us").and_then(|v| v.as_u64()).unwrap();
    let spans = r.get("spans").expect("trace: true adds spans");
    let stage_sum: u64 = ["queue_us", "route_us", "stage_us", "execute_us", "finish_us"]
        .iter()
        .map(|k| spans.get(k).and_then(|v| v.as_u64()).unwrap())
        .sum();
    assert_eq!(stage_sum, latency, "{spans:?}");
    assert!(spans.get("linger_us").and_then(|v| v.as_u64()).is_some());

    // req_id correlation: echoed on success (numbers too), on errors,
    // and server-assigned when the client sends none
    let r = request(&mut stream, &mut reader, r#"{"op": "ping", "req_id": 7}"#);
    assert_eq!(r.get("req_id").and_then(|v| v.as_u64()), Some(7));
    assert_eq!(r.get("spans"), None);
    let r = request(&mut stream, &mut reader, r#"{"op": "ping"}"#);
    let rid = r.get("req_id").and_then(|v| v.as_str()).unwrap();
    assert!(rid.starts_with("srv-"), "{rid}");
    let r = request(
        &mut stream,
        &mut reader,
        r#"{"op": "bogus", "req_id": "e-9"}"#,
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(r.get("req_id").and_then(|v| v.as_str()), Some("e-9"));

    // scheduler counters over the wire (incl. the data-movement family)
    let m = request(&mut stream, &mut reader, r#"{"op": "metrics"}"#);
    assert_eq!(m.get("ok"), Some(&Json::Bool(true)));
    assert!(m.get("completed").and_then(|v| v.as_u64()).unwrap() >= 3);
    assert!(m.get("pool").and_then(|v| v.as_u64()).unwrap() >= 1);
    for key in [
        "cancelled",
        "cache_hits",
        "bytes_to_device",
        "pipelined_batches",
        "prefetched",
        "rehomed",
    ] {
        assert!(m.get(key).and_then(|v| v.as_u64()).is_some(), "missing {key}");
    }
    // default config: cache off, nothing elided
    assert_eq!(m.get("cache_hits").and_then(|v| v.as_u64()), Some(0));
    // the cost model's live crossover estimates ride along: the cold
    // gemm crossover sits in the paper's Figure-3 band, and warm-B
    // undercuts it only when the operand cache is on (off here => equal)
    let x = m.get("crossover_estimate").expect("missing crossover_estimate");
    let gemm_n = x.get("gemm_n").and_then(|v| v.as_u64()).unwrap();
    assert!(gemm_n > 64 && gemm_n <= 128, "gemm crossover {gemm_n}");
    assert_eq!(x.get("gemv_n").and_then(|v| v.as_u64()), Some(0));
    assert_eq!(x.get("level1_n").and_then(|v| v.as_u64()), Some(0));
    // latency percentiles: overall plus the per-op-class breakdown
    for key in ["p50_us", "p99_us", "p999_us"] {
        assert!(m.get(key).and_then(|v| v.as_u64()).unwrap() > 0, "missing {key}");
    }
    let lat = m.get("latency").expect("missing latency");
    for class in ["gemm", "gemv", "level1", "chain"] {
        let l = lat.get(class).unwrap_or_else(|| panic!("missing class {class}"));
        assert!(l.get("p99_us").and_then(|v| v.as_u64()).is_some());
    }
    let g = lat.get("gemm").unwrap();
    assert!(g.get("count").and_then(|v| v.as_u64()).unwrap() >= 3);
    assert!(g.get("p99_us").and_then(|v| v.as_u64()).unwrap() > 0);
    // aggregate span breakdown: execute time must have accumulated
    let s = m.get("spans").expect("missing spans");
    assert!(s.get("execute_us").and_then(|v| v.as_u64()).unwrap() > 0);

    // the live per-cluster view
    let t = request(&mut stream, &mut reader, r#"{"op": "top"}"#);
    assert_eq!(t.get("ok"), Some(&Json::Bool(true)), "{t:?}");
    let clusters = match t.get("clusters") {
        Some(Json::Arr(a)) => a,
        other => panic!("missing clusters array: {other:?}"),
    };
    assert!(!clusters.is_empty());
    for c in clusters {
        for key in ["cluster", "queue_depth", "inflight", "cache_hits", "stolen", "p99_us"] {
            assert!(c.get(key).and_then(|v| v.as_u64()).is_some(), "missing {key}");
        }
        // everything has been replied to: the inflight gauge is drained
        assert_eq!(c.get("inflight").and_then(|v| v.as_u64()), Some(0));
    }

    // shutdown stops the server thread
    let _ = request(&mut stream, &mut reader, r#"{"op": "shutdown"}"#);
    handle.join().unwrap().unwrap();
}
