//! The multi-cluster scheduler end-to-end: K concurrent clients all
//! complete with correct checksums, queue-full backpressure returns the
//! retry error deterministically, and same-shape requests coalesce.

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use common::artifacts_dir;
use hero_blas::config::{DispatchMode, PlatformConfig};
use hero_blas::sched::{GemmRequest, JobPayload, Priority, Scheduler, SubmitError};
use hero_blas::util::json_lite::Json;
use hero_blas::util::rng::Rng;

fn cfg(pool: u32, queue: u32, window_ms: u64, batch_max: u32) -> PlatformConfig {
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = pool;
    cfg.sched.queue_capacity = queue;
    cfg.sched.batch_window_ms = window_ms;
    cfg.sched.batch_max = batch_max;
    cfg
}

/// The checksum a request (n, seed) must produce: operands are drawn
/// from the seeded RNG exactly like the worker draws them, multiplied
/// with a plain triple loop.
fn expected_checksum(n: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let a = rng.normal_vec(n * n);
    let b = rng.normal_vec(n * n);
    let mut sum = 0.0;
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            for j in 0..n {
                sum += aik * b[k * n + j];
            }
        }
    }
    sum
}

fn request(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Json {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response '{resp}': {e}"))
}

#[test]
fn concurrent_clients_complete_with_correct_checksums() {
    let dir = artifacts_dir();
    let (tx, rx) = mpsc::channel();
    let server = std::thread::spawn(move || {
        hero_blas::serve::serve(cfg(4, 64, 2, 8), &dir, 0, Some(tx))
    });
    let port = rx.recv_timeout(Duration::from_secs(300)).unwrap();

    const CLIENTS: usize = 6;
    const PER_CLIENT: usize = 3;
    let mut clients = Vec::new();
    for c in 0..CLIENTS {
        clients.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            // one client exercises the host path, the rest offload
            let mode = if c == 0 { "host_only" } else { "device_only" };
            let mut results = Vec::new();
            for i in 0..PER_CLIENT {
                let seed = 1_000 + (c * PER_CLIENT + i) as u64;
                let r = request(
                    &mut stream,
                    &mut reader,
                    &format!(
                        r#"{{"op": "gemm", "n": 64, "mode": "{mode}", "seed": {seed}}}"#
                    ),
                );
                assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
                let checksum = r.get("checksum").and_then(|v| v.as_f64()).unwrap();
                let cluster = r.get("cluster").and_then(|v| v.as_u64()).unwrap();
                let batch = r.get("batch_size").and_then(|v| v.as_u64()).unwrap();
                assert!(cluster < 4, "cluster {cluster} out of pool");
                assert!(batch >= 1);
                results.push((seed, checksum));
            }
            results
        }));
    }

    for client in clients {
        for (seed, checksum) in client.join().unwrap() {
            let expect = expected_checksum(64, seed);
            let tol = 1e-6 * expect.abs().max(1.0);
            assert!(
                (checksum - expect).abs() < tol,
                "seed {seed}: checksum {checksum} != expected {expect}"
            );
        }
    }

    // shutdown
    let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let _ = request(&mut stream, &mut reader, r#"{"op": "shutdown"}"#);
    server.join().unwrap().unwrap();
}

/// Deterministic backpressure: park the single worker on a fence, fill
/// the bounded queue exactly, and watch the next submit bounce with a
/// retry hint.  No timing races — the worker cannot drain while parked.
#[test]
fn queue_full_backpressure_returns_retry_error() {
    let sched = Scheduler::new(&cfg(1, 2, 0, 1), &artifacts_dir()).unwrap();

    // park the only worker
    let (release, fence_rx) = mpsc::channel();
    let fence_done = sched
        .submit(Priority::High, JobPayload::Fence(fence_rx))
        .expect("fence submit");
    let t0 = Instant::now();
    while sched.queue_depth() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never took the fence");
        std::thread::sleep(Duration::from_millis(1));
    }

    // fill the queue to capacity behind the parked worker
    let gemm = |seed| {
        JobPayload::Gemm(GemmRequest {
            n: 32,
            mode: DispatchMode::DeviceOnly,
            seed,
            b_seed: None,
        })
    };
    let r1 = sched.submit(Priority::Normal, gemm(1)).expect("fits");
    let r2 = sched.submit(Priority::Normal, gemm(2)).expect("fits");

    // the queue is full and the worker is parked: rejection is certain
    match sched.submit(Priority::Normal, gemm(3)) {
        Err(SubmitError::Backpressure { depth, retry_after_ms }) => {
            assert_eq!(depth, 2);
            assert!(retry_after_ms >= 1);
        }
        other => panic!("expected backpressure, got {other:?}"),
    }
    let m = sched.metrics();
    assert_eq!(m.rejected, 1);
    assert_eq!(m.submitted, 3); // fence + 2 queued gemms

    // release the fence: everything drains and completes
    release.send(()).unwrap();
    assert!(fence_done.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
    let a = r1.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
    let b = r2.recv_timeout(Duration::from_secs(120)).unwrap().unwrap();
    assert_eq!(a.n, 32);
    assert_eq!(b.cluster, 0);
    assert_eq!(sched.metrics().completed, 3);

    // after the backlog clears, submits are accepted again
    let r3 = sched.submit(Priority::Normal, gemm(3)).expect("accepted after drain");
    assert!(r3.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
    sched.shutdown();
}

/// Same-shape requests queued behind a fence coalesce into ONE fork-join
/// launch; each member reports the shared batch and the amortized
/// per-request fork/join cost is below a solo launch's.
#[test]
fn batching_coalesces_and_amortizes_fork_join() {
    let sched = Scheduler::new(&cfg(1, 32, 0, 8), &artifacts_dir()).unwrap();

    // solo baseline: one un-batched launch
    let solo = sched
        .submit(
            Priority::Normal,
            JobPayload::Gemm(GemmRequest {
                n: 64,
                mode: DispatchMode::DeviceOnly,
                seed: 7,
                b_seed: None,
            }),
        )
        .unwrap()
        .recv_timeout(Duration::from_secs(300))
        .unwrap()
        .unwrap();
    assert_eq!(solo.batch_size, 1);
    assert!(solo.fork_join_ms > 0.0);

    // park the worker, queue 4 identical-shape requests, release
    let (release, fence_rx) = mpsc::channel();
    let fence_done =
        sched.submit(Priority::High, JobPayload::Fence(fence_rx)).unwrap();
    let t0 = Instant::now();
    while sched.queue_depth() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never took the fence");
        std::thread::sleep(Duration::from_millis(1));
    }
    let receivers: Vec<_> = (0..4)
        .map(|i| {
            sched
                .submit(
                    Priority::Normal,
                    JobPayload::Gemm(GemmRequest {
                        n: 64,
                        mode: DispatchMode::DeviceOnly,
                        seed: 100 + i,
                        b_seed: None,
                    }),
                )
                .unwrap()
        })
        .collect();
    release.send(()).unwrap();
    assert!(fence_done.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());

    for rx in receivers {
        let out = rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
        assert_eq!(out.batch_size, 4, "expected all four to share one launch");
        // fork/join paid once for the batch => each member's share is
        // well under the solo cost
        assert!(
            out.fork_join_ms < solo.fork_join_ms * 0.5,
            "no amortization: batched {} vs solo {}",
            out.fork_join_ms,
            solo.fork_join_ms
        );
        // members keep their own operands (seeds differ from the solo run;
        // per-seed checksum correctness is pinned by the first test)
        assert!(out.checksum != solo.checksum);
    }
    let m = sched.metrics();
    assert_eq!(m.batched_jobs, 4);
    sched.shutdown();
}

/// A job whose submitter cancelled (serve-layer reply timeout) is
/// skipped at dequeue: never launched, counted in `cancelled`, and its
/// reply channel just closes.
#[test]
fn cancelled_jobs_are_skipped_at_dequeue() {
    let sched = Scheduler::new(&cfg(1, 8, 0, 1), &artifacts_dir()).unwrap();

    // park the only worker so the jobs stay queued
    let (release, fence_rx) = mpsc::channel();
    let fence_done = sched
        .submit(Priority::High, JobPayload::Fence(fence_rx))
        .expect("fence submit");
    let t0 = Instant::now();
    while sched.queue_depth() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never took the fence");
        std::thread::sleep(Duration::from_millis(1));
    }

    let gemm = |seed| {
        JobPayload::Gemm(GemmRequest {
            n: 32,
            mode: DispatchMode::DeviceOnly,
            seed,
            b_seed: None,
        })
    };
    let doomed = sched.submit(Priority::Normal, gemm(1)).expect("fits");
    let alive = sched.submit(Priority::Normal, gemm(2)).expect("fits");
    doomed.cancel.cancel(); // the submitter gave up while queued

    release.send(()).unwrap();
    assert!(fence_done.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());

    // the live job completes normally...
    let out = alive.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
    assert_eq!(out.n, 32);
    // ...the cancelled one was dropped without a result (sender closed)
    assert!(doomed.result.recv_timeout(Duration::from_secs(120)).is_err());
    let m = sched.metrics();
    assert_eq!(m.cancelled, 1);
    assert_eq!(m.completed, 2); // fence + live gemm, not the cancelled one
    sched.shutdown();
}

/// The expected gemv checksum: same synthesis as the worker (A then x
/// from the request RNG, y = A @ x), plain loops.
fn expected_gemv_checksum(m: usize, n: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let a = rng.normal_vec(m * n);
    let x = rng.normal_vec(n);
    (0..m)
        .map(|i| (0..n).map(|j| a[i * n + j] * x[j]).sum::<f64>())
        .sum()
}

/// Same-shape GEMV requests queued behind a fence coalesce into ONE
/// fork-join launch (the level-2 batching path), with correct checksums
/// and amortized fork/join.
#[test]
fn gemv_requests_batch_into_one_launch() {
    use hero_blas::sched::GemvRequest;
    let sched = Scheduler::new(&cfg(1, 32, 0, 8), &artifacts_dir()).unwrap();

    // solo baseline
    let solo = sched
        .submit(
            Priority::Normal,
            JobPayload::Gemv(GemvRequest {
                m: 64,
                n: 64,
                mode: DispatchMode::DeviceOnly,
                seed: 7,
            }),
        )
        .unwrap()
        .recv_timeout(Duration::from_secs(300))
        .unwrap()
        .unwrap();
    assert_eq!((solo.op, solo.batch_size), ("gemv", 1));
    assert!(solo.fork_join_ms > 0.0);
    let tol = 1e-6 * solo.checksum.abs().max(1.0);
    assert!((solo.checksum - expected_gemv_checksum(64, 64, 7)).abs() < tol);

    // park, queue 4 same-shape gemvs, release
    let (release, fence_rx) = mpsc::channel();
    let fence_done =
        sched.submit(Priority::High, JobPayload::Fence(fence_rx)).unwrap();
    let t0 = Instant::now();
    while sched.queue_depth() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never took the fence");
        std::thread::sleep(Duration::from_millis(1));
    }
    let receivers: Vec<_> = (0..4)
        .map(|i| {
            sched
                .submit(
                    Priority::Normal,
                    JobPayload::Gemv(GemvRequest {
                        m: 64,
                        n: 64,
                        mode: DispatchMode::DeviceOnly,
                        seed: 200 + i,
                    }),
                )
                .unwrap()
        })
        .collect();
    release.send(()).unwrap();
    assert!(fence_done.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());

    for (i, rx) in receivers.into_iter().enumerate() {
        let out = rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
        assert_eq!(out.batch_size, 4, "expected all four to share one launch");
        assert_eq!((out.op, out.m, out.n), ("gemv", 64, 64));
        assert!(
            out.fork_join_ms < solo.fork_join_ms * 0.5,
            "no amortization: batched {} vs solo {}",
            out.fork_join_ms,
            solo.fork_join_ms
        );
        let expect = expected_gemv_checksum(64, 64, 200 + i as u64);
        let tol = 1e-6 * expect.abs().max(1.0);
        assert!((out.checksum - expect).abs() < tol, "member {i} checksum");
    }
    sched.shutdown();
}

/// Tentpole acceptance: on a repeated shared-B workload the operand
/// cache + software pipeline cut host->device copy bytes by >= 2x and
/// hide map-in under compute, while every checksum stays identical to
/// the plain (cache-off, unpiped) scheduler's.
#[test]
fn cache_and_pipeline_cut_copies_checksums_identical() {
    // batch_max 1 (each request launches alone) so consecutive launches
    // exercise the stage-under-compute pipeline deterministically
    let mut plain_cfg = cfg(1, 32, 0, 1);
    plain_cfg.sched.cache.cache_frac = 0.0;
    plain_cfg.sched.cache.pipeline_depth = 1;
    let mut fast_cfg = cfg(1, 32, 0, 1);
    fast_cfg.sched.cache.cache_frac = 0.4;
    fast_cfg.sched.cache.cache_max_entries = 16;
    fast_cfg.sched.cache.pipeline_depth = 2;

    let run = |cfg: &hero_blas::config::PlatformConfig| {
        let sched = Scheduler::new(cfg, &artifacts_dir()).unwrap();
        // park the worker so all requests are queued back-to-back — the
        // pipelined worker then always has a next batch to stage early
        let (release, fence_rx) = mpsc::channel();
        let fence_done =
            sched.submit(Priority::High, JobPayload::Fence(fence_rx)).unwrap();
        let t0 = Instant::now();
        while sched.queue_depth() > 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "fence not taken");
            std::thread::sleep(Duration::from_millis(1));
        }
        let receivers: Vec<_> = (0..4)
            .map(|i| {
                sched
                    .submit(
                        Priority::Normal,
                        JobPayload::Gemm(GemmRequest {
                            n: 64,
                            mode: DispatchMode::DeviceOnly,
                            seed: 500 + i,
                            b_seed: Some(42), // the shared weight matrix
                        }),
                    )
                    .unwrap()
            })
            .collect();
        release.send(()).unwrap();
        assert!(fence_done.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
        let checksums: Vec<f64> = receivers
            .into_iter()
            .map(|rx| {
                rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap().checksum
            })
            .collect();
        let m = sched.metrics();
        sched.shutdown();
        (checksums, m)
    };

    let (plain_sums, plain_m) = run(&plain_cfg);
    let (fast_sums, fast_m) = run(&fast_cfg);

    // results are bit-identical: the cache shares bytes, never mutates
    assert_eq!(plain_sums, fast_sums, "cache/pipeline must not change results");

    // the shared B hits the cache and the beta==0 C staging is elided
    assert!(fast_m.cache_hits > 0, "no cache hits: {}", fast_m.summary());
    assert_eq!(plain_m.cache_hits, 0);
    assert!(
        fast_m.bytes_to_device * 2 <= plain_m.bytes_to_device,
        "copy bytes not halved: {} vs {}",
        fast_m.bytes_to_device,
        plain_m.bytes_to_device
    );

    // back-to-back launches pipelined, with map-in hidden under compute
    assert!(fast_m.pipelined_batches > 0, "{}", fast_m.summary());
    assert!(fast_m.overlap_hidden_us > 0, "{}", fast_m.summary());
    assert_eq!(plain_m.pipelined_batches, 0);
}
