//! Shared helpers for integration tests (require `make artifacts`).

// Each integration-test binary compiles this module separately and uses
// only a subset of the helpers; the unused ones are not dead code.
#![allow(dead_code)]

use std::path::PathBuf;

use hero_blas::blas::{DispatchPolicy, HeroBlas};
use hero_blas::config::{DispatchMode, PlatformConfig};

/// Locate the artifacts directory for tests: env override, then the repo
/// root (cargo runs integration tests from the package root).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("HERO_BLAS_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let repo = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let dir = repo.join("artifacts");
    assert!(
        dir.join("manifest.json").is_file(),
        "artifacts missing at {} — run `make artifacts` first",
        dir.display()
    );
    dir
}

/// Fresh session with a given dispatch mode.
pub fn session(mode: DispatchMode) -> HeroBlas {
    HeroBlas::new(
        PlatformConfig::default(),
        &artifacts_dir(),
        DispatchPolicy::with_mode(mode),
    )
    .expect("session construction")
}

/// Max |a - b|.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}
