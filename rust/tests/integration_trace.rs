//! The flight recorder end-to-end: drive a live server, then check that
//! `trace_dump` events reconcile exactly with the traced request's
//! `SpanBreakdown`, that `metrics_prom` renders a scrapeable exposition,
//! and that `watch` streams `top` frames until the client disconnects.
//!
//! Pins the ISSUE-8 acceptance criterion: every stage boundary of a
//! traced request appears as an event pair in the dump, and the
//! durations agree with the reply's `spans` object within clock
//! precision (the offsets are floor-rounded independently, so adjacent
//! boundaries may disagree by a microsecond or two — never more).

mod common;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use common::artifacts_dir;
use hero_blas::config::PlatformConfig;
use hero_blas::util::json_lite::Json;

/// The five telescoping stages, in serving-path order, by the bare
/// names `EventKind::label` renders (the reply suffixes `_us`).
const STAGES: [&str; 5] = ["queue", "route", "stage", "execute", "finish"];

/// Boundary tolerance in microseconds: each event offset and duration
/// is floor-rounded from the same `Instant` pair independently, so two
/// adjacent stage boundaries can disagree by at most 2 us.
const CLOCK_SLOP_US: u64 = 2;

fn request(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, line: &str) -> Json {
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    Json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response '{resp}': {e}"))
}

/// One decoded `ph: "X"` span event from the dump.
#[derive(Debug, Clone)]
struct SpanEvt {
    name: String,
    ts: u64,
    dur: u64,
}

#[test]
fn trace_dump_reconciles_with_span_breakdown() {
    let dir = artifacts_dir();
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        hero_blas::serve::serve(PlatformConfig::default(), &dir, 0, Some(tx))
    });
    let port = rx.recv_timeout(std::time::Duration::from_secs(300)).unwrap();

    let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // one traced device-path gemm: the reply carries the SpanBreakdown
    // the dump must reconcile with
    let r = request(
        &mut stream,
        &mut reader,
        r#"{"op": "gemm", "n": 96, "mode": "device_only", "trace": true, "req_id": "tr-1"}"#,
    );
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    let cluster = r.get("cluster").and_then(|v| v.as_u64()).unwrap();
    let spans = r.get("spans").expect("trace: true adds spans");
    let want: Vec<u64> = STAGES
        .iter()
        .map(|s| {
            spans
                .get(&format!("{s}_us"))
                .and_then(|v| v.as_u64())
                .unwrap_or_else(|| panic!("missing {s}_us in {spans:?}"))
        })
        .collect();
    let latency = r.get("latency_us").and_then(|v| v.as_u64()).unwrap();
    assert_eq!(want.iter().sum::<u64>(), latency, "stages telescope to latency");

    // the dump: Chrome trace JSON with ok / enabled / recorded and the
    // request's correlation id merged in
    let dump = request(
        &mut stream,
        &mut reader,
        r#"{"op": "trace_dump", "req_id": "td-1"}"#,
    );
    assert_eq!(dump.get("ok"), Some(&Json::Bool(true)), "{dump:?}");
    assert_eq!(dump.get("req_id").and_then(|v| v.as_str()), Some("td-1"));
    assert_eq!(dump.get("enabled"), Some(&Json::Bool(true)));
    assert!(dump.get("recorded").and_then(|v| v.as_u64()).unwrap() >= 5);
    assert_eq!(dump.get("displayTimeUnit").and_then(|v| v.as_str()), Some("ms"));
    let events = dump
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    assert!(!events.is_empty());

    // group the stage-named duration events on the serving cluster's
    // track by job id (args.a); exactly one group must carry this
    // request's five stage durations verbatim
    let tid = cluster + 1;
    let mut by_job: HashMap<u64, Vec<SpanEvt>> = HashMap::new();
    for e in events {
        let name = e.get("name").and_then(|v| v.as_str()).unwrap().to_string();
        if e.get("ph").and_then(|v| v.as_str()) != Some("X")
            || e.get("tid").and_then(|v| v.as_u64()) != Some(tid)
            || !STAGES.contains(&name.as_str())
        {
            continue;
        }
        by_job
            .entry(e.get("args").and_then(|a| a.get("a")).and_then(|v| v.as_u64()).unwrap())
            .or_default()
            .push(SpanEvt {
                name,
                ts: e.get("ts").and_then(|v| v.as_u64()).unwrap(),
                dur: e.get("dur").and_then(|v| v.as_u64()).unwrap(),
            });
    }
    let matches: Vec<(&u64, &Vec<SpanEvt>)> = by_job
        .iter()
        .filter(|(_, evts)| {
            STAGES.iter().zip(&want).all(|(s, w)| {
                evts.iter().any(|e| e.name == *s && e.dur == *w)
            })
        })
        .collect();
    assert_eq!(
        matches.len(),
        1,
        "exactly one dumped job must carry the reply's stage durations \
         {want:?}; groups: {by_job:?}"
    );
    let (&job_id, evts) = matches[0];

    // every stage boundary appears as an event pair: stage k's end
    // (ts + dur) is stage k+1's start, within clock precision
    let ordered: Vec<&SpanEvt> = STAGES
        .iter()
        .map(|s| evts.iter().find(|e| e.name == *s).unwrap())
        .collect();
    for w in ordered.windows(2) {
        let end = w[0].ts + w[0].dur;
        let start = w[1].ts;
        assert!(
            end.abs_diff(start) <= CLOCK_SLOP_US,
            "{} ends at {end} but {} starts at {start}",
            w[0].name,
            w[1].name
        );
    }

    // the same job's life-cycle instants are on the record too: ingress
    // on the global track (tid 0), with instants typed ph "i"
    let enqueued = events.iter().any(|e| {
        e.get("name").and_then(|v| v.as_str()) == Some("job-enqueued")
            && e.get("ph").and_then(|v| v.as_str()) == Some("i")
            && e.get("tid").and_then(|v| v.as_u64()) == Some(0)
            && e.get("args").and_then(|a| a.get("a")).and_then(|v| v.as_u64())
                == Some(job_id)
    });
    assert!(enqueued, "job {job_id} has no job-enqueued ingress instant");

    // prometheus exposition over the wire: correlation id, content
    // type, and the counter + histogram families with sane values
    let prom = request(
        &mut stream,
        &mut reader,
        r#"{"op": "metrics_prom", "req_id": "mp-1"}"#,
    );
    assert_eq!(prom.get("ok"), Some(&Json::Bool(true)), "{prom:?}");
    assert_eq!(prom.get("req_id").and_then(|v| v.as_str()), Some("mp-1"));
    assert_eq!(
        prom.get("content_type").and_then(|v| v.as_str()),
        Some("text/plain; version=0.0.4")
    );
    let body = prom.get("body").and_then(|v| v.as_str()).unwrap();
    for needle in [
        "# TYPE hero_jobs_submitted_total counter",
        "# TYPE hero_request_latency_us histogram",
        "hero_request_latency_us_bucket{op=\"gemm\",le=\"+Inf\"} ",
        "hero_request_latency_us_count{op=\"gemm\"} ",
        "hero_cluster_latency_us_count{cluster=\"0\"} ",
        "hero_span_us_total{stage=\"execute\"} ",
        "hero_pin_leaks_total 0",
    ] {
        assert!(body.contains(needle), "missing '{needle}' in exposition");
    }
    // exposition hygiene: every line is a comment or `name value`
    for line in body.lines() {
        assert!(
            line.starts_with('#') || line.split(' ').count() == 2,
            "malformed exposition line: '{line}'"
        );
    }

    // the top rows now surface pin_leaks alongside quarantined
    let t = request(&mut stream, &mut reader, r#"{"op": "top"}"#);
    assert_eq!(t.get("ok"), Some(&Json::Bool(true)), "{t:?}");
    assert_eq!(t.get("pin_leaks").and_then(|v| v.as_u64()), Some(0));
    let clusters = t.get("clusters").and_then(|v| v.as_arr()).unwrap();
    for c in clusters {
        assert_eq!(c.get("pin_leaks").and_then(|v| v.as_u64()), Some(0));
        assert_eq!(c.get("quarantined"), Some(&Json::Bool(false)));
    }

    // watch: a second connection streams top frames every interval
    // until the client hangs up — the server must survive the hangup
    let mut wstream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut wreader = BufReader::new(wstream.try_clone().unwrap());
    wstream
        .write_all(b"{\"op\": \"watch\", \"req_id\": \"w-1\", \"interval_ms\": 10}\n")
        .unwrap();
    wstream.flush().unwrap();
    for _ in 0..3 {
        let mut frame = String::new();
        wreader.read_line(&mut frame).unwrap();
        let f = Json::parse(frame.trim())
            .unwrap_or_else(|e| panic!("bad watch frame '{frame}': {e}"));
        assert_eq!(f.get("ok"), Some(&Json::Bool(true)), "{f:?}");
        assert_eq!(f.get("req_id").and_then(|v| v.as_str()), Some("w-1"));
        let rows = f.get("clusters").and_then(|v| v.as_arr()).unwrap();
        assert!(!rows.is_empty());
        for row in rows {
            for key in ["cluster", "queue_depth", "inflight", "pin_leaks"] {
                assert!(row.get(key).and_then(|v| v.as_u64()).is_some(), "missing {key}");
            }
            assert!(
                matches!(row.get("quarantined"), Some(Json::Bool(_))),
                "missing quarantined"
            );
        }
    }
    drop(wreader);
    drop(wstream);

    // the original connection still serves after the watcher hung up
    let pong = request(&mut stream, &mut reader, r#"{"op": "ping"}"#);
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

    let _ = request(&mut stream, &mut reader, r#"{"op": "shutdown"}"#);
    handle.join().unwrap().unwrap();
}
