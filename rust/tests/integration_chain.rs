//! Chained multi-op execution end-to-end: device-resident intermediates
//! must change *data movement*, never numerics.
//!
//! Pins the ISSUE-5 acceptance criteria: chained-vs-unchained checksum
//! identity (bit-for-bit), the `chain_bytes_elided` counter, cancel-
//! mid-chain pin release, whole-chain placement/steal behavior, and the
//! clear capacity error for chains no slice can stage.

mod common;

use common::artifacts_dir;
use hero_blas::blas::{ChainLink, DispatchPolicy, HeroBlas};
use hero_blas::config::{DispatchMode, PlatformConfig};
use hero_blas::npy::NdArray;
use hero_blas::sched::{ChainRequest, JobPayload, Priority, Scheduler};
use hero_blas::util::rng::Rng;

fn session_with(cfg: PlatformConfig, mode: DispatchMode) -> HeroBlas {
    HeroBlas::new(cfg, &artifacts_dir(), DispatchPolicy::with_mode(mode))
        .expect("session construction")
}

/// Synthesize the MLP-shaped workload: activation from `seed`, weights
/// from `b_seeds` (own stream) or the continuing request stream —
/// exactly like the scheduler's worker.
fn synth(m: usize, dims: &[usize], seed: u64, b_seeds: &[Option<u64>])
         -> (Vec<f64>, Vec<Vec<f64>>) {
    let mut rng = Rng::new(seed);
    let x = rng.normal_vec(m * dims[0]);
    let weights = dims
        .windows(2)
        .zip(b_seeds)
        .map(|(w, bs)| match bs {
            Some(bs) => Rng::new(*bs).normal_vec(w[0] * w[1]),
            None => rng.normal_vec(w[0] * w[1]),
        })
        .collect();
    (x, weights)
}

#[test]
fn chained_device_run_is_bit_identical_to_per_op_and_elides_bytes() {
    let (m, dims) = (64usize, vec![96usize, 64, 96]);
    let (x, weights) = synth(m, &dims, 7, &[None, None]);
    let links: Vec<ChainLink<'_, f64>> = dims
        .windows(2)
        .zip(weights.iter())
        .map(|(w, b)| ChainLink { b, dims: (w[0], w[1]), bias: None, relu: false })
        .collect();

    // unchained oracle: each link its own device offload, intermediates
    // round-tripping through the host
    let mut per_op = session_with(PlatformConfig::default(), DispatchMode::DeviceOnly);
    let mut h = x.clone();
    for (w, b) in dims.windows(2).zip(&weights) {
        let (k, n) = (w[0], w[1]);
        let mut c = vec![0.0; m * n];
        per_op
            .gemm(
                hero_blas::blas::Transpose::No,
                hero_blas::blas::Transpose::No,
                1.0,
                &h,
                (m, k),
                b,
                (k, n),
                0.0,
                &mut c,
                (m, n),
            )
            .unwrap();
        h = c;
    }
    let per_op_bytes = per_op.metrics().bytes_to_device;

    // chained run: one submission, intermediates device-resident
    let mut chained = session_with(PlatformConfig::default(), DispatchMode::DeviceOnly);
    let mut out = vec![0.0; m * dims[dims.len() - 1]];
    chained.chain(m, &x, &links, &mut out).unwrap();
    let cm = chained.metrics();

    assert_eq!(out, h, "chained result must be BIT-identical to per-op");
    assert!(cm.chain_bytes_elided > 0, "no intermediate bytes elided");
    // the 64x64 f64 intermediate is elided in both directions
    assert_eq!(cm.chain_bytes_elided, 2 * (m * 64 * 8) as u64);
    assert!(
        cm.bytes_to_device < per_op_bytes,
        "chained map-in bytes {} not below per-op {}",
        cm.bytes_to_device,
        per_op_bytes
    );
    assert_eq!(cm.offloads, 1, "a chain is ONE fork-join");
    // everything released: no pins, no device allocations
    assert_eq!(chained.engine.opcache.total_pins(), 0);
    assert_eq!(chained.engine.device.dram.stats().bytes_in_use, 0);
}

#[test]
fn chain_epilogues_match_the_host_path() {
    // relu(x W1 + b1) W2 through the lazy Expr builder, host vs device
    let mut rng = Rng::new(0xE5);
    let x = NdArray::<f64>::randn(&mut rng, &[48, 96]);
    let w1 = NdArray::<f64>::randn(&mut rng, &[96, 64]);
    let b1 = NdArray::<f64>::randn(&mut rng, &[64]);
    let w2 = NdArray::<f64>::randn(&mut rng, &[64, 32]);

    let mut host = session_with(PlatformConfig::default(), DispatchMode::HostOnly);
    let want = x.lazy().matmul(&w1).add(&b1).relu().matmul(&w2).eval(&mut host).unwrap();

    let mut dev = session_with(PlatformConfig::default(), DispatchMode::DeviceOnly);
    let got = x.lazy().matmul(&w1).add(&b1).relu().matmul(&w2).eval(&mut dev).unwrap();

    assert_eq!(want.shape(), &[48, 32]);
    assert_eq!(got.shape(), &[48, 32]);
    let diff = want.max_abs_diff(&got);
    assert!(diff < 1e-9, "host vs chained-device diverged by {diff}");
    assert!(dev.metrics().chain_bytes_elided > 0);

    // builder shape errors surface at eval with clear messages
    let bad = x.lazy().matmul(&w2);
    assert!(bad.eval(&mut host).is_err(), "mismatched link must fail");
    let bad = x.lazy().add(&b1);
    assert!(bad.eval(&mut host).is_err(), "bias before any matmul must fail");
}

#[test]
fn cancelled_chain_releases_pins_and_device_memory() {
    // cache ON so staged weights pin operand-cache entries — the leak
    // the abandon path must not allow
    let mut cfg = PlatformConfig::default();
    cfg.sched.cache.cache_frac = 0.4;
    cfg.sched.cache.cache_max_entries = 32;
    let mut blas = session_with(cfg, DispatchMode::DeviceOnly);

    let (m, dims) = (64usize, vec![64usize, 64, 64]);
    let (x, weights) = synth(m, &dims, 3, &[Some(41), Some(42)]);
    let links: Vec<ChainLink<'_, f64>> = dims
        .windows(2)
        .zip(weights.iter())
        .map(|(w, b)| ChainLink { b, dims: (w[0], w[1]), bias: None, relu: false })
        .collect();

    let staged = blas.chain_stage(m, &x, &links).unwrap();
    assert!(
        blas.engine.opcache.total_pins() > 0,
        "staged chain must pin its cached operands"
    );
    let in_use = blas.engine.device.dram.stats().bytes_in_use;
    assert!(in_use > 0, "staged chain must occupy device DRAM");

    // REPLY_TIMEOUT fired: the submitter is gone — abandon must release
    // every pin and every map(alloc:) output
    blas.chain_abandon(staged);
    assert_eq!(blas.engine.opcache.total_pins(), 0, "stranded cache pins");
    // unpinned cache entries may stay resident (that is the point of the
    // cache); everything NOT cache-owned must be freed
    let resident = blas.engine.opcache.bytes_resident();
    assert_eq!(
        blas.engine.device.dram.stats().bytes_in_use,
        resident,
        "abandoned chain stranded non-cache device allocations"
    );

    // the session stays fully usable: the same chain runs to completion
    let mut out = vec![0.0; m * 64];
    blas.chain(m, &x, &links, &mut out).unwrap();
    assert_eq!(blas.engine.opcache.total_pins(), 0);
}

#[test]
fn scheduler_serves_chains_whole_with_identical_checksums() {
    // pool of 2 with stealing on: chains route/steal as ONE unit, and
    // chained vs unchained submissions agree bit-for-bit
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = 2;
    cfg.sched.queue_capacity = 32;
    cfg.sched.batch_window_ms = 0;
    cfg.sched.cache.cache_frac = 0.4;
    let sched = Scheduler::new(&cfg, &artifacts_dir()).unwrap();

    let request = |seed: u64, chained: bool| ChainRequest {
        m: 48,
        dims: vec![96, 64, 32],
        mode: DispatchMode::DeviceOnly,
        seed,
        b_seeds: vec![Some(7), Some(8)],
        chained,
    };

    let mut chained_sums = Vec::new();
    let mut unchained_sums = Vec::new();
    for chained in [true, false] {
        let subs: Vec<_> = (0..6)
            .map(|s| {
                sched
                    .submit(Priority::Normal, JobPayload::Chain(request(s, chained)))
                    .expect("submit chain")
            })
            .collect();
        for sub in subs {
            let outcome = sub
                .result
                .recv_timeout(std::time::Duration::from_secs(300))
                .expect("chain reply")
                .expect("chain outcome");
            assert_eq!(outcome.op, "chain");
            assert_eq!((outcome.m, outcome.n), (48, 32));
            assert!(outcome.cluster < 2, "chain served by one pool cluster");
            if chained {
                chained_sums.push(outcome.checksum);
            } else {
                unchained_sums.push(outcome.checksum);
            }
        }
    }
    assert_eq!(
        chained_sums, unchained_sums,
        "chained checksums must match per-op execution bit-for-bit"
    );

    let m = sched.metrics();
    assert_eq!(m.chains, 12, "every chain submission counted");
    assert!(m.chain_bytes_elided > 0, "chained runs must elide bytes");
    assert_eq!(m.failed, 0);
    sched.shutdown();
}

#[test]
fn oversized_chains_fail_fast_with_a_clear_error() {
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = 4; // small slices: ~16 MiB each
    cfg.sched.queue_capacity = 8;
    let sched = Scheduler::new(&cfg, &artifacts_dir()).unwrap();

    // 6 links of 640x640 f64 stage ~26 MiB resident at once — more than
    // any 16 MiB slice can hold
    let big = ChainRequest {
        m: 640,
        dims: vec![640; 7],
        mode: DispatchMode::DeviceOnly,
        seed: 1,
        b_seeds: vec![None; 6],
        chained: true,
    };
    let err = sched.validate_chain(&big).unwrap_err();
    assert!(err.contains("slice"), "unhelpful capacity error: {err}");

    // too many links for [sched.chain] max_links
    let long = ChainRequest {
        m: 16,
        dims: vec![16; 10],
        mode: DispatchMode::DeviceOnly,
        seed: 1,
        b_seeds: vec![None; 9],
        chained: true,
    };
    let err = sched.validate_chain(&long).unwrap_err();
    assert!(err.contains("max_links"), "unhelpful link-bound error: {err}");

    // a fitting chain passes the same gate
    let ok = ChainRequest {
        m: 64,
        dims: vec![64, 64],
        mode: DispatchMode::DeviceOnly,
        seed: 1,
        b_seeds: vec![None],
        chained: true,
    };
    assert!(sched.validate_chain(&ok).is_ok());
    sched.shutdown();
}
