//! The placement router end-to-end: affinity routing cuts the shared
//! operand to ~one cold copy per pool, stealing drains a skewed run
//! queue with bit-identical checksums, oversized shapes land on the
//! big-shape lane instead of erroring, level-1 requests coalesce, and
//! the gemv path pipelines.

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use common::artifacts_dir;
use hero_blas::config::{DispatchMode, PlatformConfig};
use hero_blas::sched::affinity::operand_key;
use hero_blas::sched::{
    GemmRequest, GemvRequest, JobPayload, Level1Op, Level1Request, Priority,
    Scheduler,
};
use hero_blas::util::rng::Rng;

fn cfg(pool: u32, batch_max: u32) -> PlatformConfig {
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = pool;
    cfg.sched.queue_capacity = 64;
    cfg.sched.batch_window_ms = 0;
    cfg.sched.batch_max = batch_max;
    cfg
}

fn gemm(n: usize, seed: u64, b_seed: Option<u64>) -> JobPayload {
    JobPayload::Gemm(GemmRequest {
        n,
        mode: DispatchMode::DeviceOnly,
        seed,
        b_seed,
    })
}

/// Park a worker on a fence and wait until it is claimed.
fn park(sched: &Scheduler) -> (mpsc::Sender<()>, hero_blas::sched::Submission) {
    let (release, fence_rx) = mpsc::channel();
    let fence = sched
        .submit(Priority::High, JobPayload::Fence(fence_rx))
        .expect("fence submit");
    let t0 = Instant::now();
    while sched.queue_depth() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "fence never claimed");
        std::thread::sleep(Duration::from_millis(1));
    }
    (release, fence)
}

/// The checksum a shared-B request (n, seed, b_seed) must produce.
fn expected_checksum_b(n: usize, seed: u64, b_seed: u64) -> f64 {
    let a = Rng::new(seed).normal_vec(n * n);
    let b = Rng::new(b_seed).normal_vec(n * n);
    let mut sum = 0.0;
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            for j in 0..n {
                sum += aik * b[k * n + j];
            }
        }
    }
    sum
}

/// ISSUE 3 acceptance: on the shared-B workload with pool 2, affinity
/// routing stages B once per POOL (one cold miss) where round-robin
/// placement stages it once per CLUSTER — visible in `bytes_to_device`
/// and the per-cluster cache-hit counters.
#[test]
fn affinity_routing_warms_one_cluster_and_cuts_copies() {
    let run = |affinity: bool| {
        let mut c = cfg(2, 1);
        c.sched.cache.cache_frac = 0.4;
        c.sched.cache.cache_max_entries = 16;
        c.sched.placement.affinity = affinity;
        c.sched.placement.steal = false; // isolate routing from stealing
        let sched = Scheduler::new(&c, &artifacts_dir()).unwrap();
        let mut clusters = Vec::new();
        for i in 0..6u64 {
            let out = sched
                .submit(Priority::Normal, gemm(64, 100 + i, Some(42)))
                .unwrap()
                .recv_timeout(Duration::from_secs(300))
                .unwrap()
                .unwrap();
            let expect = expected_checksum_b(64, 100 + i, 42);
            let tol = 1e-6 * expect.abs().max(1.0);
            assert!((out.checksum - expect).abs() < tol, "req {i} checksum");
            clusters.push(out.cluster);
        }
        let m = sched.metrics();
        sched.shutdown();
        (clusters, m)
    };

    let (rr_clusters, rr) = run(false);
    let (af_clusters, af) = run(true);

    // affinity: every request on ONE cluster, deterministically
    assert!(
        af_clusters.iter().all(|&c| c == af_clusters[0]),
        "affine stream split across clusters: {af_clusters:?}"
    );
    assert_eq!(af.affine_routed, 6);
    assert_eq!(rr.affine_routed, 0);
    // round-robin spread the stream (both clusters served something)
    assert!(rr_clusters.iter().any(|&c| c != rr_clusters[0]), "{rr_clusters:?}");

    // shared B staged once per pool vs once per cluster.  With affinity
    // the single cold copy happens as a directory-driven PREFETCH (the
    // worker pre-stages B at its cold home), so every one of the 6
    // batch map-ins hits; round-robin pays one cold in-batch miss per
    // cluster and hits the other 4 times.
    assert_eq!(af.cache_hits, 6, "{}", af.summary());
    assert_eq!(af.prefetched, 1, "{}", af.summary());
    assert_eq!(rr.cache_hits, 4, "{}", rr.summary());
    assert!(
        af.bytes_to_device < rr.bytes_to_device,
        "affinity did not cut cold copies: {} vs {}",
        af.bytes_to_device,
        rr.bytes_to_device
    );

    // per-cluster breakdown: the warm cluster owns all hits and batches
    let warm = af_clusters[0] as usize;
    assert_eq!(af.clusters[warm].cache_hits, 6);
    assert_eq!(af.clusters[warm].prefetched, 1);
    assert_eq!(af.clusters[warm].affine_routed, 6);
    assert_eq!(af.clusters[1 - warm].completed, 0);
}

/// ISSUE 3 acceptance: under skew (every job affine to a fenced
/// cluster) the idle peer steals the backlog — steal counter > 0 and
/// checksums bit-identical to the placement-off (unstolen) run.
#[test]
fn steal_under_skew_matches_unstolen_checksums() {
    // a b_seed whose hash-home is cluster 0 (where the fence parks)
    let bs = (0..64)
        .find(|&s| operand_key("gemm_b", 64, s) % 2 == 0)
        .expect("some seed homes on cluster 0");

    let run = |steal: bool| {
        let mut c = cfg(2, 1);
        c.sched.placement.affinity = true;
        c.sched.placement.steal = steal;
        let sched = Scheduler::new(&c, &artifacts_dir()).unwrap();
        // the first fence routes to cluster 0 deterministically
        let (release, fence) = park(&sched);
        let subs: Vec<_> = (0..4u64)
            .map(|i| {
                (
                    300 + i,
                    sched
                        .submit(Priority::Normal, gemm(64, 300 + i, Some(bs)))
                        .unwrap(),
                )
            })
            .collect();
        let mut results = Vec::new();
        for (seed, sub) in subs {
            let out = sub
                .recv_timeout(Duration::from_secs(300))
                .unwrap()
                .unwrap();
            results.push((seed, out.checksum, out.cluster));
        }
        release.send(()).unwrap();
        assert!(fence.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
        let m = sched.metrics();
        sched.shutdown();
        (results, m)
    };

    // steal on: worker 0 is parked, so the jobs can only complete if
    // worker 1 stole them — no fence release until all replies arrive
    let (stolen_results, stolen_m) = run(true);
    for (_, _, cluster) in &stolen_results {
        assert_eq!(*cluster, 1, "a parked cluster served a job");
    }
    assert_eq!(stolen_m.stolen, 4, "{}", stolen_m.summary());
    assert_eq!(stolen_m.clusters[1].stolen, 4);

    // steal off: the jobs wait for the fenced home cluster
    let run_off = |_: ()| {
        let mut c = cfg(2, 1);
        c.sched.placement.affinity = true;
        c.sched.placement.steal = false;
        let sched = Scheduler::new(&c, &artifacts_dir()).unwrap();
        let (release, fence) = park(&sched);
        let subs: Vec<_> = (0..4u64)
            .map(|i| {
                (
                    300 + i,
                    sched
                        .submit(Priority::Normal, gemm(64, 300 + i, Some(bs)))
                        .unwrap(),
                )
            })
            .collect();
        release.send(()).unwrap();
        assert!(fence.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
        let mut results = Vec::new();
        for (seed, sub) in subs {
            let out = sub
                .recv_timeout(Duration::from_secs(300))
                .unwrap()
                .unwrap();
            results.push((seed, out.checksum, out.cluster));
        }
        let m = sched.metrics();
        sched.shutdown();
        (results, m)
    };
    let (home_results, home_m) = run_off(());
    assert_eq!(home_m.stolen, 0);
    for (_, _, cluster) in &home_results {
        assert_eq!(*cluster, 0, "home-cluster run must stay on cluster 0");
    }

    // bit-identical checksums: stealing changes placement, not numerics
    for ((s1, c1, _), (s2, c2, _)) in
        stolen_results.iter().zip(home_results.iter())
    {
        assert_eq!(s1, s2);
        assert_eq!(c1, c2, "seed {s1}: stolen {c1} != unstolen {c2}");
    }
}

/// ISSUE 3 acceptance: a GEMM too large for an even pool-4 slice errors
/// under the even split but stages and completes on the big-shape lane;
/// small requests keep out of the big lane's queue.
#[test]
fn big_shape_lane_serves_oversized_gemm() {
    // steal off throughout: this test pins lane *segregation* (an idle
    // big-lane worker legitimately steals small jobs otherwise)
    // even split: 16 MiB slices cannot stage 3 * 896^2 * 8 B (~19 MB)
    let mut even = cfg(4, 1);
    even.sched.placement.steal = false;
    let sched = Scheduler::new(&even, &artifacts_dir()).unwrap();
    let err = sched
        .submit(Priority::Normal, gemm(896, 7, None))
        .unwrap()
        .recv_timeout(Duration::from_secs(300))
        .unwrap();
    assert!(err.is_err(), "even split should OOM on n=896: {err:?}");
    sched.shutdown();

    // big-shape lane: cluster 0 holds 95% of the partition
    let mut c = cfg(4, 1);
    c.sched.placement.big_shape_frac = 0.95;
    c.sched.placement.steal = false;
    let sched = Scheduler::new(&c, &artifacts_dir()).unwrap();
    let out = sched
        .submit(Priority::Normal, gemm(896, 7, None))
        .unwrap()
        .recv_timeout(Duration::from_secs(300))
        .unwrap()
        .expect("big-shape lane must stage n=896");
    assert_eq!(out.cluster, 0, "oversized job must run on the big lane");
    assert_eq!(out.n, 896);
    assert!(out.checksum.is_finite());

    // small jobs avoid the big lane (round-robin over clusters 1..3)
    for i in 0..3u64 {
        let out = sched
            .submit(Priority::Normal, gemm(64, 50 + i, None))
            .unwrap()
            .recv_timeout(Duration::from_secs(300))
            .unwrap()
            .unwrap();
        assert_ne!(out.cluster, 0, "small job routed to the big lane");
    }
    let m = sched.metrics();
    assert_eq!(m.big_shape_routed, 1, "{}", m.summary());
    sched.shutdown();
}

/// Device-DRAM arithmetic for the headline shape: the pool-4 big-shape
/// slice stages all three n=1600 f64 operands (the unpartitioned
/// range), which the even pool-4 split cannot.  Engine-level so the
/// test stays compute-free.
#[test]
fn big_slice_stages_n1600_operands() {
    use hero_blas::omp::engine::OffloadEngine;
    use hero_blas::sched::DevicePool;
    use hero_blas::soc::Platform;

    let mut base = PlatformConfig::default();
    base.sched.placement.big_shape_frac = 0.95;
    let pool = DevicePool::partition(&base, 4).unwrap();

    let n = 1600usize;
    let operand = || vec![1u8; n * n * 8];
    let (a, b, c) = (operand(), operand(), operand());

    // big lane: all three operands stage
    let big_cfg = pool.specs()[0].cfg.clone();
    let mut e = OffloadEngine::new(Platform::new(big_cfg)).unwrap();
    let ba = e.map_to(&a, false, "a").unwrap();
    let bb = e.map_to(&b, false, "b").unwrap();
    let bc = e.map_to(&c, false, "c").unwrap();
    e.unmap(ba, "a").unwrap();
    e.unmap(bb, "b").unwrap();
    e.unmap(bc, "c").unwrap();

    // a small slice (and the old even split) cannot stage even one
    let small_cfg = pool.specs()[1].cfg.clone();
    let mut e = OffloadEngine::new(Platform::new(small_cfg)).unwrap();
    assert!(e.map_to(&a, false, "a").is_err());
    let even = DevicePool::partition(&PlatformConfig::default(), 4).unwrap();
    let mut e = OffloadEngine::new(Platform::new(even.specs()[0].cfg.clone())).unwrap();
    assert!(e.map_to(&a, false, "a").is_err());
}

/// Same-length level-1 requests coalesce into ONE fork-join launch with
/// correct per-member results — the last device path that paid the
/// launch per call.
#[test]
fn level1_requests_batch_into_one_launch() {
    let sched = Scheduler::new(&cfg(1, 8), &artifacts_dir()).unwrap();
    let axpy = |seed, alpha| {
        JobPayload::Level1(Level1Request {
            op: Level1Op::Axpy,
            n: 4096,
            mode: DispatchMode::DeviceOnly,
            seed,
            alpha,
        })
    };
    let dot = |seed| {
        JobPayload::Level1(Level1Request {
            op: Level1Op::Dot,
            n: 4096,
            mode: DispatchMode::DeviceOnly,
            seed,
            alpha: 1.0,
        })
    };
    let expect_axpy = |seed: u64, alpha: f64| {
        let mut rng = Rng::new(seed);
        let x = rng.normal_vec(4096);
        let y = rng.normal_vec(4096);
        x.iter().zip(&y).map(|(xi, yi)| alpha * xi + yi).sum::<f64>()
    };
    let expect_dot = |seed: u64| {
        let mut rng = Rng::new(seed);
        let x = rng.normal_vec(4096);
        let y = rng.normal_vec(4096);
        x.iter().zip(&y).map(|(xi, yi)| xi * yi).sum::<f64>()
    };

    // solo baseline: one un-batched launch
    let solo = sched
        .submit(Priority::Normal, axpy(7, 1.5))
        .unwrap()
        .recv_timeout(Duration::from_secs(300))
        .unwrap()
        .unwrap();
    assert_eq!((solo.op, solo.batch_size), ("axpy", 1));
    assert!(solo.fork_join_ms > 0.0);
    let tol = 1e-6 * solo.checksum.abs().max(1.0);
    assert!((solo.checksum - expect_axpy(7, 1.5)).abs() < tol);

    // park, queue 4 same-length axpys (distinct alphas), release
    let (release, fence) = park(&sched);
    let receivers: Vec<_> = (0..4u64)
        .map(|i| {
            (
                i,
                sched
                    .submit(Priority::Normal, axpy(400 + i, 1.0 + i as f64))
                    .unwrap(),
            )
        })
        .collect();
    release.send(()).unwrap();
    assert!(fence.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
    for (i, rx) in receivers {
        let out = rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
        assert_eq!(out.batch_size, 4, "expected all four to share one launch");
        assert_eq!(out.op, "axpy");
        assert!(
            out.fork_join_ms < solo.fork_join_ms * 0.5,
            "no amortization: batched {} vs solo {}",
            out.fork_join_ms,
            solo.fork_join_ms
        );
        let expect = expect_axpy(400 + i, 1.0 + i as f64);
        let tol = 1e-6 * expect.abs().max(1.0);
        assert!((out.checksum - expect).abs() < tol, "member {i} checksum");
    }

    // dot coalesces too, and never with axpy (different op key)
    let (release, fence) = park(&sched);
    let receivers: Vec<_> = (0..3u64)
        .map(|i| (i, sched.submit(Priority::Normal, dot(500 + i)).unwrap()))
        .collect();
    release.send(()).unwrap();
    assert!(fence.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
    for (i, rx) in receivers {
        let out = rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
        assert_eq!((out.op, out.batch_size), ("dot", 3));
        let expect = expect_dot(500 + i);
        let tol = 1e-6 * expect.abs().max(1.0);
        assert!((out.checksum - expect).abs() < tol, "member {i} checksum");
    }
    sched.shutdown();
}

/// The gemv device path pipelines like gemm: back-to-back gemv batches
/// overlap map-in with compute, with checksums identical to the
/// unpipelined scheduler.
#[test]
fn gemv_pipeline_overlaps_with_identical_checksums() {
    let gemv = |seed| {
        JobPayload::Gemv(GemvRequest {
            m: 64,
            n: 64,
            mode: DispatchMode::DeviceOnly,
            seed,
        })
    };
    let run = |pipeline: bool| {
        let mut c = cfg(1, 1);
        c.sched.cache.cache_frac = if pipeline { 0.4 } else { 0.0 };
        c.sched.cache.cache_max_entries = 16;
        c.sched.cache.pipeline_depth = if pipeline { 2 } else { 1 };
        let sched = Scheduler::new(&c, &artifacts_dir()).unwrap();
        let (release, fence) = park(&sched);
        let receivers: Vec<_> = (0..4u64)
            .map(|i| sched.submit(Priority::Normal, gemv(600 + i)).unwrap())
            .collect();
        release.send(()).unwrap();
        assert!(fence.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
        let sums: Vec<f64> = receivers
            .into_iter()
            .map(|rx| {
                let out =
                    rx.recv_timeout(Duration::from_secs(300)).unwrap().unwrap();
                assert_eq!(out.op, "gemv");
                out.checksum
            })
            .collect();
        let m = sched.metrics();
        sched.shutdown();
        (sums, m)
    };

    let (plain_sums, plain_m) = run(false);
    let (fast_sums, fast_m) = run(true);
    assert_eq!(plain_sums, fast_sums, "pipelining must not change results");
    assert!(fast_m.pipelined_batches > 0, "{}", fast_m.summary());
    assert!(fast_m.overlap_hidden_us > 0, "{}", fast_m.summary());
    assert_eq!(plain_m.pipelined_batches, 0);
}

/// The serve `metrics` op reports the per-cluster breakdown (queue
/// depth, cache hits, stolen / affinity-routed counts) next to the pool
/// aggregates.
#[test]
fn serve_metrics_reports_per_cluster_breakdown() {
    use hero_blas::util::json_lite::Json;

    let dir = artifacts_dir();
    let mut c = cfg(2, 8);
    c.sched.cache.cache_frac = 0.4;
    let (tx, rx) = mpsc::channel();
    let server =
        std::thread::spawn(move || hero_blas::serve::serve(c, &dir, 0, Some(tx)));
    let port = rx.recv_timeout(Duration::from_secs(300)).unwrap();

    let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut request = |line: &str| -> Json {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    };

    for seed in 0..4 {
        let r = request(&format!(
            r#"{{"op": "gemm", "n": 64, "mode": "device_only",
                "seed": {seed}, "b_seed": 42}}"#
        ));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
    }
    let m = request(r#"{"op": "metrics"}"#);
    assert_eq!(m.get("ok"), Some(&Json::Bool(true)));
    let affine = m.get("affine_routed").and_then(|v| v.as_u64()).unwrap();
    assert!(affine >= 4, "affinity routing not reported: {m:?}");
    let clusters = m.get("clusters").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(clusters.len(), 2);
    let completed_sum: u64 = clusters
        .iter()
        .map(|c| c.get("completed").and_then(|v| v.as_u64()).unwrap())
        .sum();
    let total = m.get("completed").and_then(|v| v.as_u64()).unwrap();
    assert_eq!(completed_sum, total);
    for c in clusters {
        for key in ["queue_depth", "stolen", "affine_routed", "cache_hits"] {
            assert!(c.get(key).is_some(), "missing per-cluster field {key}");
        }
    }

    let _ = request(r#"{"op": "shutdown"}"#);
    server.join().unwrap().unwrap();
}
