//! The unified cost model end-to-end: model-driven Auto dispatch on the
//! serve path, bit-identical checksums with calibration on vs off, and
//! the steal-fairness re-homing pass under a sustained affine skew.

mod common;

use std::sync::mpsc;
use std::time::{Duration, Instant};

use common::artifacts_dir;
use hero_blas::config::{DispatchMode, PlatformConfig};
use hero_blas::sched::affinity::operand_key;
use hero_blas::sched::{
    GemmOutcome, GemmRequest, GemvRequest, JobPayload, Priority, Scheduler,
};
use hero_blas::util::rng::Rng;

fn cfg(pool: u32) -> PlatformConfig {
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = pool;
    cfg.sched.queue_capacity = 64;
    cfg.sched.batch_window_ms = 0;
    cfg.sched.batch_max = 1;
    cfg
}

fn gemm_auto(n: usize, seed: u64) -> JobPayload {
    JobPayload::Gemm(GemmRequest { n, mode: DispatchMode::Auto, seed, b_seed: None })
}

fn run_one(sched: &Scheduler, payload: JobPayload) -> GemmOutcome {
    sched
        .submit(Priority::Normal, payload)
        .unwrap()
        .recv_timeout(Duration::from_secs(300))
        .unwrap()
        .unwrap()
}

/// Model-driven Auto dispatch on the serve path: sizes below the
/// crossover run on the host (no fork-join spent), sizes above offload —
/// and a huge Auto-mode GEMV runs on the host too (the admission bugfix:
/// copy-mode level-2 never beats the host cold, so no fork-join is
/// wasted on it).
#[test]
fn auto_serve_requests_dispatch_through_the_model() {
    let sched = Scheduler::new(&cfg(1), &artifacts_dir()).unwrap();

    let small = run_one(&sched, gemm_auto(16, 7));
    assert!(small.host_compute_ms > 0.0, "16x16 must stay on host");
    assert_eq!(small.fork_join_ms, 0.0, "host path spent a fork-join");

    let large = run_one(&sched, gemm_auto(128, 8));
    assert!(large.data_copy_ms > 0.0, "128x128 must offload");
    assert!(large.fork_join_ms > 0.0);
    assert_eq!(large.host_compute_ms, 0.0);

    // Auto-mode GEMV above the OLD static threshold (512*512): the model
    // keeps it on the host — the copy of A alone costs more than the
    // host compute — instead of wasting a fork-join + 2 MiB of staging
    let gemv = run_one(
        &sched,
        JobPayload::Gemv(GemvRequest {
            m: 512,
            n: 512,
            mode: DispatchMode::Auto,
            seed: 9,
        }),
    );
    assert!(gemv.host_compute_ms > 0.0, "auto gemv must stay on host");
    assert_eq!(gemv.fork_join_ms, 0.0);
    sched.shutdown();
}

/// The bit-identity guarantee: `[cost] calibrate` on vs off produces
/// identical checksums on an identical workload (calibration moves
/// dispatch decisions and linger windows, never numerics — and on this
/// single-stream workload the decisions agree too), and Auto-mode
/// checksums equal the forced-mode checksums of the path the model
/// picked.
#[test]
fn calibrate_toggle_is_checksum_identical() {
    let run = |calibrate: bool| {
        let mut c = cfg(2);
        c.cost.calibrate = calibrate;
        let sched = Scheduler::new(&c, &artifacts_dir()).unwrap();
        let mut sums = Vec::new();
        for seed in 0..4u64 {
            sums.push(run_one(&sched, gemm_auto(16, 100 + seed)).checksum);
            sums.push(run_one(&sched, gemm_auto(128, 200 + seed)).checksum);
        }
        sched.shutdown();
        sums
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off, on, "calibration toggle changed checksums");

    // dispatch parity with the forced paths: Auto@16 == host_only@16,
    // Auto@128 == device_only@128, bit for bit
    let sched = Scheduler::new(&cfg(1), &artifacts_dir()).unwrap();
    let forced = |n: usize, seed: u64, mode: DispatchMode| {
        run_one(
            &sched,
            JobPayload::Gemm(GemmRequest { n, mode, seed, b_seed: None }),
        )
        .checksum
    };
    assert_eq!(
        run_one(&sched, gemm_auto(16, 500)).checksum,
        forced(16, 500, DispatchMode::HostOnly),
    );
    assert_eq!(
        run_one(&sched, gemm_auto(128, 501)).checksum,
        forced(128, 501, DispatchMode::DeviceOnly),
    );
    sched.shutdown();
}

/// Steal-fairness satellite: with stealing off and the affine home
/// parked on a fence, a sustained same-operand stream is stuck behind
/// the saturated home — unless the re-homing pass moves the key, after
/// which later requests complete on the idle peer while the home is
/// still parked (the affine queueing delay drops from "until the fence
/// releases" to "immediately").
#[test]
fn sustained_skew_rehomes_and_cuts_affine_queueing_delay() {
    // a b_seed whose hash-home is cluster 0 (where the first fence parks)
    let bs = (0..64)
        .find(|&s| operand_key("gemm_b", 64, s) % 2 == 0)
        .expect("some seed homes on cluster 0");
    let gemm_b = |seed: u64| {
        JobPayload::Gemm(GemmRequest {
            n: 64,
            mode: DispatchMode::DeviceOnly,
            seed,
            b_seed: Some(bs),
        })
    };

    let run = |rebalance: u32| {
        let mut c = cfg(2);
        c.sched.placement.affinity = true;
        c.sched.placement.steal = false;
        c.sched.placement.rebalance_drains = rebalance;
        let sched = Scheduler::new(&c, &artifacts_dir()).unwrap();
        // park cluster 0's worker (the first fence routes there)
        let (release, fence) = {
            let (release, fence_rx) = mpsc::channel();
            let fence = sched
                .submit(Priority::High, JobPayload::Fence(fence_rx))
                .expect("fence submit");
            let t0 = Instant::now();
            while sched.queue_depth() > 0 {
                assert!(t0.elapsed() < Duration::from_secs(10), "fence unclaimed");
                std::thread::sleep(Duration::from_millis(1));
            }
            (release, fence)
        };
        // a sustained affine stream at the parked home; spaced submits so
        // each one is a separate job-moving drain pass for the router
        let subs: Vec<_> = (0..8u64)
            .map(|i| {
                let s = sched.submit(Priority::Normal, gemm_b(700 + i)).unwrap();
                std::thread::sleep(Duration::from_millis(50));
                s
            })
            .collect();
        // while the home is STILL parked: does the tail of the stream
        // complete?  (only possible if its jobs were re-homed)
        let last = subs.last().unwrap();
        let served_while_parked = match rebalance {
            0 => last.result.recv_timeout(Duration::from_millis(500)).is_ok(),
            _ => last.result.recv_timeout(Duration::from_secs(120)).is_ok(),
        };
        release.send(()).unwrap();
        assert!(fence.recv_timeout(Duration::from_secs(120)).unwrap().is_ok());
        // every job still completes with the right checksum
        let a_sum = |seed: u64| {
            let a = Rng::new(seed).normal_vec(64 * 64);
            let b = Rng::new(bs).normal_vec(64 * 64);
            let mut sum = 0.0;
            for i in 0..64 {
                for k in 0..64 {
                    for j in 0..64 {
                        sum += a[i * 64 + k] * b[k * 64 + j];
                    }
                }
            }
            sum
        };
        for (i, sub) in subs.iter().enumerate() {
            if i == subs.len() - 1 && served_while_parked {
                continue; // already drained above
            }
            let out = sub
                .result
                .recv_timeout(Duration::from_secs(300))
                .unwrap()
                .unwrap();
            let expect = a_sum(700 + i as u64);
            let tol = 1e-6 * expect.abs().max(1.0);
            assert!((out.checksum - expect).abs() < tol, "job {i} checksum");
        }
        let m = sched.metrics();
        sched.shutdown();
        (served_while_parked, m)
    };

    // rebalance on: the key re-homes to the idle peer and the tail is
    // served while the home is still parked
    let (served, m) = run(2);
    assert!(served, "re-homed jobs did not reach the idle peer");
    assert!(m.rehomed >= 1, "{}", m.summary());
    assert!(m.clusters[1].completed >= 1, "{}", m.summary());

    // rebalance off: with stealing off too, nothing serves the stream
    // until the fence releases — the tail cannot complete while parked
    let (served_off, m_off) = run(0);
    assert!(!served_off, "tail completed with rebalancing disabled");
    assert_eq!(m_off.rehomed, 0);
}
