//! End-to-end GEMV and level-1 offloads vs host kernels.

mod common;

use common::{max_abs_diff, session};
use hero_blas::blas::{host, Transpose};
use hero_blas::config::DispatchMode;
use hero_blas::util::rng::Rng;

#[test]
fn device_gemv_matches_host() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(21);
    for &(m, n) in &[(1usize, 1usize), (5, 9), (64, 64), (70, 130), (128, 128)] {
        let a = rng.normal_vec(m * n);
        let x = rng.normal_vec(n);
        let y0 = rng.normal_vec(m);
        let mut y_dev = y0.clone();
        blas.gemv(Transpose::No, 2.0, &a, (m, n), &x, -0.25, &mut y_dev)
            .unwrap();
        let mut y_ref = y0.clone();
        host::gemv(m, n, 2.0, &a, &x, -0.25, &mut y_ref);
        let err = max_abs_diff(&y_dev, &y_ref);
        assert!(err < 1e-10, "gemv ({m},{n}): err {err}");
    }
}

#[test]
fn device_gemv_transposed() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(22);
    let (rows, cols) = (48, 80); // op(A) = 80x48
    let a = rng.normal_vec(rows * cols);
    let x = rng.normal_vec(rows);
    let mut y_dev = vec![0.0; cols];
    blas.gemv(Transpose::Yes, 1.0, &a, (rows, cols), &x, 0.0, &mut y_dev)
        .unwrap();
    let a_t = host::materialize_op(&a, rows, cols, Transpose::Yes);
    let mut y_ref = vec![0.0; cols];
    host::gemv(cols, rows, 1.0, &a_t, &x, 0.0, &mut y_ref);
    assert!(max_abs_diff(&y_dev, &y_ref) < 1e-10);
}

#[test]
fn device_axpy_matches_host_including_tails() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(23);
    // 5000 is not a multiple of the 4096/1024 artifact sizes: forces the
    // chunking + tail-padding path.
    for &n in &[1usize, 100, 1024, 4096, 5000, 10000] {
        let x = rng.normal_vec(n);
        let y0 = rng.normal_vec(n);
        let mut y_dev = y0.clone();
        blas.axpy(1.5, &x, &mut y_dev).unwrap();
        let mut y_ref = y0.clone();
        host::axpy(1.5, &x, &mut y_ref);
        let err = max_abs_diff(&y_dev, &y_ref);
        assert!(err < 1e-12, "axpy n={n}: err {err}");
    }
}

#[test]
fn device_dot_matches_host() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(24);
    for &n in &[1usize, 511, 1024, 9000] {
        let x = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        let d_dev = blas.dot(&x, &y).unwrap();
        let d_ref = host::dot(&x, &y);
        assert!((d_dev - d_ref).abs() < 1e-9 * (1.0 + d_ref.abs()),
                "dot n={n}: {d_dev} vs {d_ref}");
    }
}

#[test]
fn host_only_level1_helpers() {
    let mut blas = session(DispatchMode::HostOnly);
    let mut x = vec![3.0, -4.0];
    assert_eq!(blas.nrm2(&x).unwrap(), 5.0);
    assert_eq!(blas.asum(&x).unwrap(), 7.0);
    assert_eq!(blas.iamax(&x).unwrap(), 1);
    blas.scal(2.0, &mut x).unwrap();
    assert_eq!(x, vec![6.0, -8.0]);
    let y = vec![1.0, 1.0];
    assert_eq!(blas.dot(&x, &y).unwrap(), -2.0);
}

#[test]
fn syrk_host_only_even_in_device_mode() {
    // the paper compiles syrk.c host-only; forcing device mode must not
    // offload it (device_kernels gate)
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(25);
    let (n, k) = (32, 16);
    let a = rng.normal_vec(n * k);
    let mut c = vec![0.0; n * n];
    blas.reset_run();
    blas.syrk(
        hero_blas::blas::Uplo::Lower,
        Transpose::No,
        1.0,
        &a,
        (n, k),
        0.0,
        &mut c,
        n,
    )
    .unwrap();
    assert_eq!(blas.engine.metrics.offloads, 0, "syrk must stay on host");
    // numerics vs direct host call
    let mut c_ref = vec![0.0; n * n];
    host::syrk(n, k, 1.0, &a, 0.0, &mut c_ref, hero_blas::blas::Uplo::Lower);
    assert_eq!(c, c_ref);
}

#[test]
fn length_mismatches_rejected() {
    let mut blas = session(DispatchMode::HostOnly);
    let x = vec![0.0; 4];
    let mut y = vec![0.0; 5];
    assert!(blas.axpy(1.0, &x, &mut y).is_err());
    assert!(blas.dot(&x, &y).is_err());
    let a = vec![0.0; 12];
    let mut y3 = vec![0.0; 3];
    assert!(blas
        .gemv(Transpose::No, 1.0, &a, (3, 4), &x, 0.0, &mut y3)
        .is_ok());
    assert!(blas
        .gemv(Transpose::No, 1.0, &a, (3, 4), &y3.clone(), 0.0, &mut y3)
        .is_err());
}
