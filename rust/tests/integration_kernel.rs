//! Bit-identity of specialized fast-path walks vs the generic walk.
//!
//! A promoted kernel plan changes ONLY the charge schedule (virtual
//! time): the specialized walk issues the exact same PJRT executions as
//! the generic interpreted walk, so its outputs must be bit-identical —
//! every comparison here is `assert_eq!`, not an epsilon band.

mod common;

use std::sync::Arc;

use common::session;
use hero_blas::blas::{ChainLink, HeroBlas, Transpose};
use hero_blas::config::{DispatchMode, KernelConfig};
use hero_blas::kernel::{Epilogue, KernelRegistry};
use hero_blas::util::rng::Rng;

/// Attach a fresh registry to a session, keyed with the same manifest
/// tile geometry and level-1 chunk the device staging path resolves —
/// the scheduler does exactly this at pool boot (`sched::Scheduler`).
fn attach_registry(blas: &mut HeroBlas, promote_after: u32) -> Arc<KernelRegistry> {
    let man = blas.registry.manifest();
    let tile = (man.tile_m, man.tile_n, man.tile_k);
    let level1_chunk = man
        .entries
        .iter()
        .filter(|e| (e.op == "axpy" || e.op == "dot") && e.dtype == "f64")
        .filter_map(|e| e.n)
        .max()
        .unwrap_or(4096);
    let reg = Arc::new(KernelRegistry::new(
        &KernelConfig { promote_after, ..KernelConfig::default() },
        tile,
        level1_chunk,
    ));
    blas.policy.kernel = Some(Arc::clone(&reg));
    reg
}

/// Feed the launch counter past the promotion threshold so the next
/// device staging of this (op, dtype, dims, epilogue) compiles and runs
/// the specialized walk (in production the scheduler's outcome stream
/// is the only feed).
fn promote(reg: &KernelRegistry, op: &str, dtype: &str, dims: (usize, usize, usize), epi: Epilogue) {
    let key = reg.key_for(op, dtype, dims, epi).expect("specializable op");
    for _ in 0..reg.promote_after() {
        reg.note_launch(key);
    }
}

// Edge shapes deliberately off the tile grid (tile is 64^3 by default):
// sub-tile, exact-tile, ragged-both-ways, and a padded tall-skinny.
const GEMM_SHAPES: [(usize, usize, usize); 4] =
    [(5, 9, 7), (64, 64, 64), (70, 130, 50), (1, 65, 128)];

#[test]
fn specialized_gemm_bit_identical_f64() {
    let mut generic = session(DispatchMode::DeviceOnly);
    let mut spec = session(DispatchMode::DeviceOnly);
    let reg = attach_registry(&mut spec, 1);
    let mut rng = Rng::new(31);
    for &(m, n, k) in &GEMM_SHAPES {
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        let c0 = rng.normal_vec(m * n);
        promote(&reg, "gemm", "f64", (m, n, k), Epilogue::None);
        let mut c_spec = c0.clone();
        spec.gemm(
            Transpose::No, Transpose::No, 1.5, &a, (m, k), &b, (k, n), -0.5,
            &mut c_spec, (m, n),
        )
        .unwrap();
        let mut c_gen = c0.clone();
        generic
            .gemm(
                Transpose::No, Transpose::No, 1.5, &a, (m, k), &b, (k, n),
                -0.5, &mut c_gen, (m, n),
            )
            .unwrap();
        assert_eq!(c_spec, c_gen, "gemm f64 ({m},{n},{k}) must be bit-identical");
    }
    let s = reg.stats();
    assert_eq!(s.specialized as usize, GEMM_SHAPES.len(), "one plan per shape");
    assert!(s.hits >= GEMM_SHAPES.len() as u64, "every walk must hit its plan");
    assert_eq!(s.fallbacks, 0, "promoted shapes must not fall back");
}

#[test]
fn specialized_gemm_bit_identical_f32() {
    let mut generic = session(DispatchMode::DeviceOnly);
    let mut spec = session(DispatchMode::DeviceOnly);
    let reg = attach_registry(&mut spec, 1);
    let mut rng = Rng::new(32);
    for &(m, n, k) in &GEMM_SHAPES {
        let a: Vec<f32> = rng.normal_vec(m * k).iter().map(|&v| v as f32).collect();
        let b: Vec<f32> = rng.normal_vec(k * n).iter().map(|&v| v as f32).collect();
        let c0: Vec<f32> = rng.normal_vec(m * n).iter().map(|&v| v as f32).collect();
        promote(&reg, "gemm", "f32", (m, n, k), Epilogue::None);
        let mut c_spec = c0.clone();
        spec.gemm(
            Transpose::No, Transpose::No, 1.0f32, &a, (m, k), &b, (k, n),
            0.0f32, &mut c_spec, (m, n),
        )
        .unwrap();
        let mut c_gen = c0.clone();
        generic
            .gemm(
                Transpose::No, Transpose::No, 1.0f32, &a, (m, k), &b, (k, n),
                0.0f32, &mut c_gen, (m, n),
            )
            .unwrap();
        assert_eq!(c_spec, c_gen, "gemm f32 ({m},{n},{k}) must be bit-identical");
    }
    assert!(reg.stats().hits > 0);
}

#[test]
fn specialized_gemv_bit_identical_f64() {
    let mut generic = session(DispatchMode::DeviceOnly);
    let mut spec = session(DispatchMode::DeviceOnly);
    let reg = attach_registry(&mut spec, 1);
    let mut rng = Rng::new(33);
    for &(m, n) in &[(5usize, 9usize), (64, 64), (70, 130), (128, 128)] {
        let a = rng.normal_vec(m * n);
        let x = rng.normal_vec(n);
        let y0 = rng.normal_vec(m);
        promote(&reg, "gemv", "f64", (m, n, 0), Epilogue::None);
        let mut y_spec = y0.clone();
        spec.gemv(Transpose::No, 2.0, &a, (m, n), &x, -0.25, &mut y_spec)
            .unwrap();
        let mut y_gen = y0.clone();
        generic
            .gemv(Transpose::No, 2.0, &a, (m, n), &x, -0.25, &mut y_gen)
            .unwrap();
        assert_eq!(y_spec, y_gen, "gemv f64 ({m},{n}) must be bit-identical");
    }
    let s = reg.stats();
    assert!(s.specialized >= 4 && s.hits >= 4);
    assert_eq!(s.fallbacks, 0);
}

#[test]
fn specialized_level1_bit_identical() {
    let mut generic = session(DispatchMode::DeviceOnly);
    let mut spec = session(DispatchMode::DeviceOnly);
    let reg = attach_registry(&mut spec, 1);
    let mut rng = Rng::new(34);
    // 5000 is not a multiple of the 4096 artifact chunk: the chunked +
    // tail-padded walk must key and run identically under a plan.
    for &n in &[100usize, 4096, 5000] {
        let x = rng.normal_vec(n);
        let y0 = rng.normal_vec(n);
        promote(&reg, "axpy", "f64", (n, 0, 0), Epilogue::None);
        let mut y_spec = y0.clone();
        spec.axpy(1.5, &x, &mut y_spec).unwrap();
        let mut y_gen = y0.clone();
        generic.axpy(1.5, &x, &mut y_gen).unwrap();
        assert_eq!(y_spec, y_gen, "axpy n={n} must be bit-identical");

        promote(&reg, "dot", "f64", (n, 0, 0), Epilogue::None);
        let d_spec = spec.dot(&x, &y_gen).unwrap();
        let d_gen = generic.dot(&x, &y_gen).unwrap();
        assert_eq!(d_spec, d_gen, "dot n={n} must be bit-identical");
    }
    let s = reg.stats();
    assert!(s.specialized >= 6, "axpy + dot plans per size: {}", s.specialized);
    assert!(s.hits >= 6);
    assert_eq!(s.fallbacks, 0);
}

#[test]
fn specialized_chain_epilogues_bit_identical() {
    // Epilogues enter a walk's key only through chain links: cover bias,
    // ReLU, and bias+ReLU fused plans against the generic chain.
    let m = 30;
    let widths = [50usize, 40, 30, 20];
    let mut rng = Rng::new(35);
    let x = rng.normal_vec(m * widths[0]);
    let b1 = rng.normal_vec(widths[0] * widths[1]);
    let b2 = rng.normal_vec(widths[1] * widths[2]);
    let b3 = rng.normal_vec(widths[2] * widths[3]);
    let bias1 = rng.normal_vec(widths[1]);
    let bias2 = rng.normal_vec(widths[2]);
    let links = [
        ChainLink { b: &b1, dims: (widths[0], widths[1]), bias: Some(&bias1), relu: true },
        ChainLink { b: &b2, dims: (widths[1], widths[2]), bias: Some(&bias2), relu: false },
        ChainLink { b: &b3, dims: (widths[2], widths[3]), bias: None, relu: true },
    ];

    let mut spec = session(DispatchMode::DeviceOnly);
    let reg = attach_registry(&mut spec, 1);
    promote(&reg, "gemm", "f64", (m, widths[1], widths[0]), Epilogue::BiasRelu);
    promote(&reg, "gemm", "f64", (m, widths[2], widths[1]), Epilogue::Bias);
    promote(&reg, "gemm", "f64", (m, widths[3], widths[2]), Epilogue::Relu);
    let mut out_spec = vec![0.0; m * widths[3]];
    spec.chain(m, &x, &links, &mut out_spec).unwrap();

    let mut generic = session(DispatchMode::DeviceOnly);
    let mut out_gen = vec![0.0; m * widths[3]];
    generic.chain(m, &x, &links, &mut out_gen).unwrap();

    assert_eq!(out_spec, out_gen, "fused-epilogue chain must be bit-identical");
    let s = reg.stats();
    assert_eq!(s.specialized, 3, "one fused plan per epilogue variant");
    assert!(s.hits >= 3);
    assert_eq!(s.fallbacks, 0);
}

#[test]
fn unpromoted_shapes_run_the_generic_fallback() {
    // With the registry attached but no launch feed, every walk stays on
    // the always-correct generic path — counted as fallbacks, numerics
    // identical to a registry-less session.
    let mut generic = session(DispatchMode::DeviceOnly);
    let mut spec = session(DispatchMode::DeviceOnly);
    let reg = attach_registry(&mut spec, 50);
    let mut rng = Rng::new(36);
    let (m, n, k) = (70, 130, 50);
    let a = rng.normal_vec(m * k);
    let b = rng.normal_vec(k * n);
    let mut c_spec = vec![0.0; m * n];
    spec.gemm(
        Transpose::No, Transpose::No, 1.0, &a, (m, k), &b, (k, n), 0.0,
        &mut c_spec, (m, n),
    )
    .unwrap();
    let mut c_gen = vec![0.0; m * n];
    generic
        .gemm(
            Transpose::No, Transpose::No, 1.0, &a, (m, k), &b, (k, n), 0.0,
            &mut c_gen, (m, n),
        )
        .unwrap();
    assert_eq!(c_spec, c_gen);
    let s = reg.stats();
    assert_eq!(s.specialized, 0, "no feed, no promotion");
    assert_eq!(s.hits, 0);
    assert!(s.fallbacks > 0, "the generic walk must be counted");
}
