//! CBLAS C-ABI surface: call the exported `cblas_*` symbols exactly as a
//! NumPy build would, including padded lda and strided vectors.

mod common;

use std::os::raw::c_int;

use hero_blas::cblas::*;
use hero_blas::util::rng::Rng;

fn init_device_mode() {
    let dir = common::artifacts_dir();
    let c = std::ffi::CString::new(dir.to_str().unwrap()).unwrap();
    let rc = unsafe { hero_blas_init(c.as_ptr(), 2) }; // device-only
    assert_eq!(rc, 0, "hero_blas_init failed");
}

#[test]
fn dgemm_matches_reference_with_padded_lda() {
    init_device_mode();
    let mut rng = Rng::new(1);
    let (m, n, k) = (65usize, 40, 50);
    let (lda, ldb, ldc) = (k + 3, n + 5, n + 2); // padded leading dims
    let a: Vec<f64> = rng.normal_vec(m * lda);
    let b: Vec<f64> = rng.normal_vec(k * ldb);
    let mut c: Vec<f64> = rng.normal_vec(m * ldc);
    let c0 = c.clone();

    unsafe {
        cblas_dgemm(
            CBLAS_ROW_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS,
            m as c_int, n as c_int, k as c_int,
            2.0, a.as_ptr(), lda as c_int, b.as_ptr(), ldb as c_int,
            -1.0, c.as_mut_ptr(), ldc as c_int,
        );
    }

    // reference on the dense gathers
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..k {
                acc += a[i * lda + p] * b[p * ldb + j];
            }
            let want = 2.0 * acc - c0[i * ldc + j];
            let got = c[i * ldc + j];
            assert!((got - want).abs() < 1e-9, "({i},{j}): {got} vs {want}");
        }
    }
    // padding columns must be untouched
    for i in 0..m {
        for j in n..ldc {
            assert_eq!(c[i * ldc + j], c0[i * ldc + j], "padding clobbered");
        }
    }
    hero_blas_shutdown();
}

#[test]
fn dgemm_transposed_against_plain() {
    init_device_mode();
    let mut rng = Rng::new(2);
    let (m, n, k) = (30usize, 20, 25);
    let a: Vec<f64> = rng.normal_vec(m * k); // row-major m x k
    let at: Vec<f64> = {
        let mut t = vec![0.0; k * m];
        for i in 0..m {
            for p in 0..k {
                t[p * m + i] = a[i * k + p];
            }
        }
        t
    };
    let b: Vec<f64> = rng.normal_vec(k * n);
    let mut c1 = vec![0.0; m * n];
    let mut c2 = vec![0.0; m * n];
    unsafe {
        cblas_dgemm(CBLAS_ROW_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS,
                    m as c_int, n as c_int, k as c_int, 1.0,
                    a.as_ptr(), k as c_int, b.as_ptr(), n as c_int,
                    0.0, c1.as_mut_ptr(), n as c_int);
        cblas_dgemm(CBLAS_ROW_MAJOR, CBLAS_TRANS, CBLAS_NO_TRANS,
                    m as c_int, n as c_int, k as c_int, 1.0,
                    at.as_ptr(), m as c_int, b.as_ptr(), n as c_int,
                    0.0, c2.as_mut_ptr(), n as c_int);
    }
    assert!(common::max_abs_diff(&c1, &c2) < 1e-10);
    hero_blas_shutdown();
}

#[test]
fn level1_and_gemv_with_strides() {
    init_device_mode();
    let n = 8;
    // x strided by 2 inside a longer buffer
    let xbuf: Vec<f64> = (0..2 * n).map(|i| i as f64).collect();
    let x: Vec<f64> = (0..n).map(|i| xbuf[2 * i]).collect();
    let mut y = vec![1.0f64; n];

    unsafe {
        cblas_daxpy(n as c_int, 0.5, xbuf.as_ptr(), 2, y.as_mut_ptr(), 1);
    }
    for i in 0..n {
        assert!((y[i] - (1.0 + 0.5 * x[i])).abs() < 1e-12);
    }

    let d = unsafe { cblas_ddot(n as c_int, xbuf.as_ptr(), 2, y.as_ptr(), 1) };
    let want: f64 = x.iter().zip(y.iter()).map(|(a, b)| a * b).sum();
    assert!((d - want).abs() < 1e-9);

    let nrm = unsafe { cblas_dnrm2(n as c_int, xbuf.as_ptr(), 2) };
    assert!((nrm - x.iter().map(|v| v * v).sum::<f64>().sqrt()).abs() < 1e-12);

    let asum = unsafe { cblas_dasum(n as c_int, xbuf.as_ptr(), 2) };
    assert!((asum - x.iter().map(|v| v.abs()).sum::<f64>()).abs() < 1e-12);

    let mut z = x.clone();
    unsafe { cblas_dscal(n as c_int, -2.0, z.as_mut_ptr(), 1) };
    for i in 0..n {
        assert_eq!(z[i], -2.0 * x[i]);
    }

    let imax = unsafe { cblas_idamax(n as c_int, z.as_ptr(), 1) };
    assert_eq!(imax as usize, n - 1); // largest |value| is the last

    // gemv: y = 1.0 * A x + 0 y
    let (m2, n2) = (5usize, 8usize);
    let a: Vec<f64> = (0..m2 * n2).map(|i| (i % 7) as f64).collect();
    let mut yv = vec![0.0f64; m2];
    unsafe {
        cblas_dgemv(CBLAS_ROW_MAJOR, CBLAS_NO_TRANS, m2 as c_int, n2 as c_int,
                    1.0, a.as_ptr(), n2 as c_int, x.as_ptr(), 1, 0.0,
                    yv.as_mut_ptr(), 1);
    }
    for i in 0..m2 {
        let want: f64 = (0..n2).map(|j| a[i * n2 + j] * x[j]).sum();
        assert!((yv[i] - want).abs() < 1e-9);
    }
    hero_blas_shutdown();
}

#[test]
fn sgemm_f32_path() {
    init_device_mode();
    let n = 16;
    let a: Vec<f32> = (0..n * n).map(|i| (i % 5) as f32).collect();
    let b: Vec<f32> = (0..n * n).map(|i| ((i + 1) % 3) as f32).collect();
    let mut c = vec![0.0f32; n * n];
    unsafe {
        cblas_sgemm(CBLAS_ROW_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS,
                    n as c_int, n as c_int, n as c_int, 1.0,
                    a.as_ptr(), n as c_int, b.as_ptr(), n as c_int,
                    0.0, c.as_mut_ptr(), n as c_int);
    }
    for i in 0..n {
        for j in 0..n {
            let want: f32 = (0..n).map(|p| a[i * n + p] * b[p * n + j]).sum();
            assert!((c[i * n + j] - want).abs() < 1e-3);
        }
    }
    hero_blas_shutdown();
}

#[test]
fn calls_without_init_fail_soft() {
    hero_blas_shutdown(); // ensure no session on this thread
    let x = [1.0f64, 2.0];
    let d = unsafe { cblas_ddot(2, x.as_ptr(), 1, x.as_ptr(), 1) };
    assert!(d.is_nan(), "uninitialized session must yield NaN, not UB");
}
