//! CBLAS C-ABI surface: call the exported `cblas_*` symbols exactly as a
//! NumPy build would, including padded lda and strided vectors.

mod common;

use std::os::raw::c_int;

use hero_blas::cblas::*;
use hero_blas::util::rng::Rng;

fn init_device_mode() {
    let dir = common::artifacts_dir();
    let c = std::ffi::CString::new(dir.to_str().unwrap()).unwrap();
    let rc = unsafe { hero_blas_init(c.as_ptr(), 2) }; // device-only
    assert_eq!(rc, 0, "hero_blas_init failed");
}

#[test]
fn dgemm_matches_reference_with_padded_lda() {
    init_device_mode();
    let mut rng = Rng::new(1);
    let (m, n, k) = (65usize, 40, 50);
    let (lda, ldb, ldc) = (k + 3, n + 5, n + 2); // padded leading dims
    let a: Vec<f64> = rng.normal_vec(m * lda);
    let b: Vec<f64> = rng.normal_vec(k * ldb);
    let mut c: Vec<f64> = rng.normal_vec(m * ldc);
    let c0 = c.clone();

    unsafe {
        cblas_dgemm(
            CBLAS_ROW_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS,
            m as c_int, n as c_int, k as c_int,
            2.0, a.as_ptr(), lda as c_int, b.as_ptr(), ldb as c_int,
            -1.0, c.as_mut_ptr(), ldc as c_int,
        );
    }

    // reference on the dense gathers
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..k {
                acc += a[i * lda + p] * b[p * ldb + j];
            }
            let want = 2.0 * acc - c0[i * ldc + j];
            let got = c[i * ldc + j];
            assert!((got - want).abs() < 1e-9, "({i},{j}): {got} vs {want}");
        }
    }
    // padding columns must be untouched
    for i in 0..m {
        for j in n..ldc {
            assert_eq!(c[i * ldc + j], c0[i * ldc + j], "padding clobbered");
        }
    }
    hero_blas_shutdown();
}

#[test]
fn dgemm_transposed_against_plain() {
    init_device_mode();
    let mut rng = Rng::new(2);
    let (m, n, k) = (30usize, 20, 25);
    let a: Vec<f64> = rng.normal_vec(m * k); // row-major m x k
    let at: Vec<f64> = {
        let mut t = vec![0.0; k * m];
        for i in 0..m {
            for p in 0..k {
                t[p * m + i] = a[i * k + p];
            }
        }
        t
    };
    let b: Vec<f64> = rng.normal_vec(k * n);
    let mut c1 = vec![0.0; m * n];
    let mut c2 = vec![0.0; m * n];
    unsafe {
        cblas_dgemm(CBLAS_ROW_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS,
                    m as c_int, n as c_int, k as c_int, 1.0,
                    a.as_ptr(), k as c_int, b.as_ptr(), n as c_int,
                    0.0, c1.as_mut_ptr(), n as c_int);
        cblas_dgemm(CBLAS_ROW_MAJOR, CBLAS_TRANS, CBLAS_NO_TRANS,
                    m as c_int, n as c_int, k as c_int, 1.0,
                    at.as_ptr(), m as c_int, b.as_ptr(), n as c_int,
                    0.0, c2.as_mut_ptr(), n as c_int);
    }
    assert!(common::max_abs_diff(&c1, &c2) < 1e-10);
    hero_blas_shutdown();
}

#[test]
fn level1_and_gemv_with_strides() {
    init_device_mode();
    let n = 8;
    // x strided by 2 inside a longer buffer
    let xbuf: Vec<f64> = (0..2 * n).map(|i| i as f64).collect();
    let x: Vec<f64> = (0..n).map(|i| xbuf[2 * i]).collect();
    let mut y = vec![1.0f64; n];

    unsafe {
        cblas_daxpy(n as c_int, 0.5, xbuf.as_ptr(), 2, y.as_mut_ptr(), 1);
    }
    for i in 0..n {
        assert!((y[i] - (1.0 + 0.5 * x[i])).abs() < 1e-12);
    }

    let d = unsafe { cblas_ddot(n as c_int, xbuf.as_ptr(), 2, y.as_ptr(), 1) };
    let want: f64 = x.iter().zip(y.iter()).map(|(a, b)| a * b).sum();
    assert!((d - want).abs() < 1e-9);

    let nrm = unsafe { cblas_dnrm2(n as c_int, xbuf.as_ptr(), 2) };
    assert!((nrm - x.iter().map(|v| v * v).sum::<f64>().sqrt()).abs() < 1e-12);

    let asum = unsafe { cblas_dasum(n as c_int, xbuf.as_ptr(), 2) };
    assert!((asum - x.iter().map(|v| v.abs()).sum::<f64>()).abs() < 1e-12);

    let mut z = x.clone();
    unsafe { cblas_dscal(n as c_int, -2.0, z.as_mut_ptr(), 1) };
    for i in 0..n {
        assert_eq!(z[i], -2.0 * x[i]);
    }

    let imax = unsafe { cblas_idamax(n as c_int, z.as_ptr(), 1) };
    assert_eq!(imax as usize, n - 1); // largest |value| is the last

    // gemv: y = 1.0 * A x + 0 y
    let (m2, n2) = (5usize, 8usize);
    let a: Vec<f64> = (0..m2 * n2).map(|i| (i % 7) as f64).collect();
    let mut yv = vec![0.0f64; m2];
    unsafe {
        cblas_dgemv(CBLAS_ROW_MAJOR, CBLAS_NO_TRANS, m2 as c_int, n2 as c_int,
                    1.0, a.as_ptr(), n2 as c_int, x.as_ptr(), 1, 0.0,
                    yv.as_mut_ptr(), 1);
    }
    for i in 0..m2 {
        let want: f64 = (0..n2).map(|j| a[i * n2 + j] * x[j]).sum();
        assert!((yv[i] - want).abs() < 1e-9);
    }
    hero_blas_shutdown();
}

#[test]
fn sgemm_f32_path() {
    init_device_mode();
    let n = 16;
    let a: Vec<f32> = (0..n * n).map(|i| (i % 5) as f32).collect();
    let b: Vec<f32> = (0..n * n).map(|i| ((i + 1) % 3) as f32).collect();
    let mut c = vec![0.0f32; n * n];
    unsafe {
        cblas_sgemm(CBLAS_ROW_MAJOR, CBLAS_NO_TRANS, CBLAS_NO_TRANS,
                    n as c_int, n as c_int, n as c_int, 1.0,
                    a.as_ptr(), n as c_int, b.as_ptr(), n as c_int,
                    0.0, c.as_mut_ptr(), n as c_int);
    }
    for i in 0..n {
        for j in 0..n {
            let want: f32 = (0..n).map(|p| a[i * n + p] * b[p * n + j]).sum();
            assert!((c[i * n + j] - want).abs() < 1e-3);
        }
    }
    hero_blas_shutdown();
}

/// Column-major dgemm against the row-major path on the SAME problem —
/// the swap-operands-and-flip identity must produce the identical
/// product, including transposes, padded leading dims and beta.  (The
/// pre-fix shim refused col-major with an eprintln and silently left C
/// untouched — any consumer computing through it got garbage.)
#[test]
fn dgemm_col_major_matches_row_major_oracle() {
    init_device_mode();
    let mut rng = Rng::new(11);
    let (m, n, k) = (33usize, 21, 17);

    for (ta, tb) in [
        (CBLAS_NO_TRANS, CBLAS_NO_TRANS),
        (CBLAS_TRANS, CBLAS_NO_TRANS),
        (CBLAS_NO_TRANS, CBLAS_TRANS),
        (CBLAS_TRANS, CBLAS_TRANS),
    ] {
        // row-major reference on dense row-major operands
        let a_dims = if ta == CBLAS_TRANS { (k, m) } else { (m, k) };
        let b_dims = if tb == CBLAS_TRANS { (n, k) } else { (k, n) };
        let a_rm: Vec<f64> = rng.normal_vec(a_dims.0 * a_dims.1);
        let b_rm: Vec<f64> = rng.normal_vec(b_dims.0 * b_dims.1);
        let c0: Vec<f64> = rng.normal_vec(m * n);
        let mut c_rm = c0.clone();
        unsafe {
            cblas_dgemm(
                CBLAS_ROW_MAJOR, ta, tb, m as c_int, n as c_int, k as c_int,
                1.5, a_rm.as_ptr(), a_dims.1 as c_int, b_rm.as_ptr(),
                b_dims.1 as c_int, -0.5, c_rm.as_mut_ptr(), n as c_int,
            );
        }

        // the same problem expressed column-major: every operand is the
        // row-major buffer transposed into col-major storage (same
        // mathematical matrix), ld = stored rows
        let to_cm = |x: &[f64], rows: usize, cols: usize| -> Vec<f64> {
            let mut out = vec![0.0; rows * cols];
            for r in 0..rows {
                for c in 0..cols {
                    out[c * rows + r] = x[r * cols + c];
                }
            }
            out
        };
        let a_cm = to_cm(&a_rm, a_dims.0, a_dims.1);
        let b_cm = to_cm(&b_rm, b_dims.0, b_dims.1);
        let mut c_cm = to_cm(&c0, m, n);
        unsafe {
            cblas_dgemm(
                CBLAS_COL_MAJOR, ta, tb, m as c_int, n as c_int, k as c_int,
                1.5, a_cm.as_ptr(), a_dims.0 as c_int, b_cm.as_ptr(),
                b_dims.0 as c_int, -0.5, c_cm.as_mut_ptr(), m as c_int,
            );
        }
        // compare element-wise across the layouts
        for i in 0..m {
            for j in 0..n {
                let (got, want) = (c_cm[j * m + i], c_rm[i * n + j]);
                assert!(
                    (got - want).abs() < 1e-9,
                    "({ta},{tb}) C({i},{j}): col-major {got} vs row-major {want}"
                );
            }
        }
    }

    // an unsupported layout value errors out WITHOUT touching C
    let a = [1.0f64, 2.0, 3.0, 4.0];
    let mut c = [9.0f64, 9.0, 9.0, 9.0];
    unsafe {
        cblas_dgemm(
            999, CBLAS_NO_TRANS, CBLAS_NO_TRANS, 2, 2, 2, 1.0, a.as_ptr(), 2,
            a.as_ptr(), 2, 0.0, c.as_mut_ptr(), 2,
        );
    }
    assert_eq!(c, [9.0, 9.0, 9.0, 9.0], "bad layout must leave C untouched");
    hero_blas_shutdown();
}

/// Column-major dgemv (both transposes) against a dense reference.
#[test]
fn dgemv_col_major_matches_reference() {
    init_device_mode();
    let mut rng = Rng::new(12);
    let (m, n) = (9usize, 13);
    let a_rm: Vec<f64> = rng.normal_vec(m * n);
    let a_cm: Vec<f64> = {
        let mut out = vec![0.0; m * n];
        for r in 0..m {
            for c in 0..n {
                out[c * m + r] = a_rm[r * n + c];
            }
        }
        out
    };
    // no-trans: y(m) = A x(n)
    let x: Vec<f64> = rng.normal_vec(n);
    let mut y = vec![0.0f64; m];
    unsafe {
        cblas_dgemv(
            CBLAS_COL_MAJOR, CBLAS_NO_TRANS, m as c_int, n as c_int, 1.0,
            a_cm.as_ptr(), m as c_int, x.as_ptr(), 1, 0.0, y.as_mut_ptr(), 1,
        );
    }
    for i in 0..m {
        let want: f64 = (0..n).map(|j| a_rm[i * n + j] * x[j]).sum();
        assert!((y[i] - want).abs() < 1e-9, "col-major gemv row {i}");
    }
    // trans: y(n) = A^T x(m)
    let xt: Vec<f64> = rng.normal_vec(m);
    let mut yt = vec![0.0f64; n];
    unsafe {
        cblas_dgemv(
            CBLAS_COL_MAJOR, CBLAS_TRANS, m as c_int, n as c_int, 1.0,
            a_cm.as_ptr(), m as c_int, xt.as_ptr(), 1, 0.0, yt.as_mut_ptr(), 1,
        );
    }
    for j in 0..n {
        let want: f64 = (0..m).map(|i| a_rm[i * n + j] * xt[i]).sum();
        assert!((yt[j] - want).abs() < 1e-9, "col-major gemv^T col {j}");
    }
    hero_blas_shutdown();
}

/// Negative increments walk the vector backwards from the end (the
/// reference CBLAS convention).  The pre-fix gather indexed *before*
/// the buffer — out-of-bounds reads producing garbage.
#[test]
fn level1_negative_strides_match_reference_semantics() {
    init_device_mode();
    let n = 6usize;
    // x stored strided-by-2; logical x with incx = -2 reads it reversed
    let xbuf: Vec<f64> = (0..2 * n).map(|i| i as f64 + 1.0).collect();
    let x_rev: Vec<f64> = (0..n).map(|i| xbuf[2 * (n - 1 - i)]).collect();
    let y0: Vec<f64> = (0..n).map(|i| 0.25 * i as f64).collect();

    // daxpy with incx = -2, incy = 1: y += a * reversed(x)
    let mut y = y0.clone();
    unsafe { cblas_daxpy(n as c_int, 2.0, xbuf.as_ptr(), -2, y.as_mut_ptr(), 1) };
    for i in 0..n {
        let want = y0[i] + 2.0 * x_rev[i];
        assert!((y[i] - want).abs() < 1e-12, "daxpy[{i}] = {} want {want}", y[i]);
    }

    // both increments negative: pairs realign, dot equals the plain dot
    let d_fwd = unsafe { cblas_ddot(n as c_int, xbuf.as_ptr(), 2, y0.as_ptr(), 1) };
    let d_rev = unsafe {
        cblas_ddot(n as c_int, xbuf.as_ptr(), -2, y0.as_ptr(), -1)
    };
    assert!((d_fwd - d_rev).abs() < 1e-12, "{d_fwd} vs {d_rev}");

    // mixed signs: y traversed forward pairs with x traversed backward
    let d_mix = unsafe { cblas_ddot(n as c_int, xbuf.as_ptr(), -2, y0.as_ptr(), 1) };
    let want: f64 = x_rev.iter().zip(&y0).map(|(a, b)| a * b).sum();
    assert!((d_mix - want).abs() < 1e-12);

    // norms/sums are traversal-order independent but must not fault
    let nrm = unsafe { cblas_dnrm2(n as c_int, xbuf.as_ptr(), -2) };
    let want_nrm = x_rev.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!((nrm - want_nrm).abs() < 1e-12);
    let asum = unsafe { cblas_dasum(n as c_int, xbuf.as_ptr(), -2) };
    assert!((asum - x_rev.iter().map(|v| v.abs()).sum::<f64>()).abs() < 1e-12);

    // idamax reports the index in backwards-traversal order: the largest
    // |value| sits at the START of the stored buffer's reversal
    let z = [1.0f64, -9.0, 3.0, 2.0];
    let i_fwd = unsafe { cblas_idamax(4, z.as_ptr(), 1) };
    assert_eq!(i_fwd, 1);
    let i_rev = unsafe { cblas_idamax(4, z.as_ptr(), -1) };
    assert_eq!(i_rev, 2, "traversal order [2.0, 3.0, -9.0, 1.0] peaks at 2");

    // n <= 0 is a clean no-op / zero, never a panic
    unsafe {
        cblas_daxpy(-3, 1.0, xbuf.as_ptr(), 1, y.as_mut_ptr(), 1);
        assert_eq!(cblas_ddot(0, xbuf.as_ptr(), 1, y0.as_ptr(), 1), 0.0);
        assert_eq!(cblas_dnrm2(-1, xbuf.as_ptr(), 1), 0.0);
        assert_eq!(cblas_dasum(0, xbuf.as_ptr(), 1), 0.0);
        assert_eq!(cblas_idamax(-2, xbuf.as_ptr(), 1), 0);
    }
    hero_blas_shutdown();
}

#[test]
fn calls_without_init_fail_soft() {
    hero_blas_shutdown(); // ensure no session on this thread
    let x = [1.0f64, 2.0];
    let d = unsafe { cblas_ddot(2, x.as_ptr(), 1, x.as_ptr(), 1) };
    assert!(d.is_nan(), "uninitialized session must yield NaN, not UB");
}
