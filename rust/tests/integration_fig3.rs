//! Calibration gates: the harness must reproduce the paper's numbers
//! (DESIGN.md §4 — R1, R2, R3, D1 and the Figure 3 shape).

mod common;

use common::artifacts_dir;
use hero_blas::config::{DispatchMode, PlatformConfig};
use hero_blas::harness::{self, fig3};

#[test]
fn r1_r2_headline_at_n128() {
    let report = harness::run_fig3(
        PlatformConfig::default(),
        &artifacts_dir(),
        &[128],
        &[DispatchMode::HostOnly, DispatchMode::DeviceOnly],
        0x5EED,
    )
    .unwrap();
    let (speedup, copy_share) = report.headline().unwrap();
    assert!(
        (speedup - fig3::PAPER_SPEEDUP_N128).abs() < 0.1,
        "speedup {speedup} vs paper {}",
        fig3::PAPER_SPEEDUP_N128
    );
    assert!(
        (copy_share - fig3::PAPER_COPY_SHARE_N128).abs() < 0.02,
        "copy share {copy_share} vs paper {}",
        fig3::PAPER_COPY_SHARE_N128
    );
}

#[test]
fn fig3_shape_crossover_and_monotonicity() {
    let report = harness::run_fig3(
        PlatformConfig::default(),
        &artifacts_dir(),
        &[16, 64, 128, 256],
        &[DispatchMode::HostOnly, DispatchMode::DeviceOnly],
        1,
    )
    .unwrap();
    // offload must LOSE at small sizes and WIN at/after 128
    let s16 = report.speedup(16, DispatchMode::DeviceOnly).unwrap();
    let s64 = report.speedup(64, DispatchMode::DeviceOnly).unwrap();
    let s128 = report.speedup(128, DispatchMode::DeviceOnly).unwrap();
    let s256 = report.speedup(256, DispatchMode::DeviceOnly).unwrap();
    assert!(s16 < 0.1, "offload at 16 must be catastrophic, got {s16}");
    assert!(s64 < 1.0, "crossover must be above 64, got {s64}");
    assert!(s128 > 2.0, "offload at 128 must win, got {s128}");
    assert!(s256 > s128, "speedup must grow with size");
    // device results stay numerically correct across the sweep
    for p in &report.points {
        assert!(p.max_abs_err < 1e-9, "n={} err={}", p.n, p.max_abs_err);
    }
}

#[test]
fn r3_zero_copy_projection() {
    let r = harness::run_zero_copy(PlatformConfig::default(), &artifacts_dir(), 128, 7).unwrap();
    let pte_ratio = r.pte_vs_copy();
    let total = r.total_speedup();
    assert!(
        (pte_ratio - harness::projections::PAPER_PTE_VS_COPY).abs() < 0.5,
        "pte-vs-copy {pte_ratio} vs paper 7.5"
    );
    // paper projects 4.7x from approximate shares; our measured value must
    // land in the same regime (well above copy-mode, near the projection)
    assert!(total > 4.2 && total < 5.0, "zero-copy total speedup {total}");
    assert!(r.copy_speedup() > 2.5 && r.copy_speedup() < 3.0);
    // functional equivalence between the three paths
    assert!(r.copy.max_abs_err < 1e-9);
    assert!(r.zero_copy.max_abs_err < 1e-9);
}

#[test]
fn d1_f32_doubles_compute() {
    let p = harness::run_f32_projection(PlatformConfig::default(), &artifacts_dir(), 128, 7)
        .unwrap();
    let cs = p.compute_speedup();
    assert!((cs - 2.0).abs() < 0.1, "f32 compute speedup {cs}");
    // end-to-end is copy-bound, so total gain must be well below 2x
    assert!(p.total_speedup() > 1.2 && p.total_speedup() < 1.8);
    assert!(p.f32_max_err < 1e-2);
}

#[test]
fn fig3_report_renders() {
    let report = harness::run_fig3(
        PlatformConfig::default(),
        &artifacts_dir(),
        &[16],
        &[DispatchMode::HostOnly, DispatchMode::DeviceOnly],
        3,
    )
    .unwrap();
    let text = report.render();
    assert!(text.contains("data_copy_ms"));
    assert!(text.contains("device_only"));
    let csv = report.csv();
    assert_eq!(csv.lines().count(), 3); // header + 2 points
}
