//! Property-style tests over coordinator invariants (randomized with the
//! in-tree deterministic RNG — seeds printed on failure for replay).
//!
//! Invariants (DESIGN.md §6): the allocator never double-allocates or
//! leaks; the data-map round-trips; the device tile walk covers every
//! output element exactly once for arbitrary shapes; region times always
//! sum to the grand total; dispatch is total and deterministic.

use hero_blas::blas::dispatch::DispatchPolicy;
use hero_blas::blas::host;
use hero_blas::config::PlatformConfig;
use hero_blas::hero::allocator::{Allocation, Arena};
use hero_blas::omp::datamap::DataMap;
use hero_blas::soc::clock::Cycles;
use hero_blas::soc::iommu::Iommu;
use hero_blas::soc::trace::{RegionClass, Trace};
use hero_blas::util::rng::Rng;

const CASES: u64 = 50;

#[test]
fn prop_allocator_invariants_random_workload() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let mut arena = Arena::new("prop", 0x1000, 1 << 16, 64);
        let mut live: Vec<Allocation> = Vec::new();
        for step in 0..200 {
            if rng.next_f64() < 0.6 || live.is_empty() {
                let len = 1 + rng.below(4096);
                if let Ok(a) = arena.alloc(len) {
                    // no overlap with any live allocation
                    for b in &live {
                        assert!(
                            a.offset + a.len <= b.offset || b.offset + b.len <= a.offset,
                            "seed {seed} step {step}: overlap {a:?} vs {b:?}"
                        );
                    }
                    live.push(a);
                }
            } else {
                let idx = rng.below(live.len() as u64) as usize;
                let a = live.swap_remove(idx);
                arena.free(a).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            }
            arena
                .check_invariants()
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
        }
        // free everything: arena must be whole again
        for a in live.drain(..) {
            arena.free(a).unwrap();
        }
        assert_eq!(arena.free_bytes(), 1 << 16, "seed {seed}: leak");
        assert_eq!(arena.fragmentation(), 0.0, "seed {seed}: fragmentation");
    }
}

#[test]
fn prop_datamap_refcounts() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xD47A);
        let mut dm = DataMap::new();
        let mut refs: std::collections::HashMap<u64, u32> = Default::default();
        for _ in 0..300 {
            let host = 0x1000 + rng.below(16) * 0x100;
            if rng.next_f64() < 0.5 {
                dm.map(host, 0xA000_0000 + host, 256).unwrap();
                *refs.entry(host).or_insert(0) += 1;
            } else if let Some(r) = refs.get_mut(&host) {
                if *r > 0 {
                    let released = dm.unmap(host).unwrap();
                    *r -= 1;
                    assert_eq!(released.is_some(), *r == 0, "seed {seed}");
                }
            } else {
                assert!(dm.unmap(host).is_err());
            }
        }
        let expect_live = refs.values().filter(|&&r| r > 0).count();
        assert_eq!(dm.live_mappings(), expect_live, "seed {seed}");
    }
}

#[test]
fn prop_iommu_map_translate_unmap() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x10CC);
        let mut iommu = Iommu::new(PlatformConfig::default().iommu);
        let mut maps = Vec::new();
        for _ in 0..20 {
            let addr = 0x10_0000 + rng.below(1 << 20);
            let len = 1 + rng.below(64 * 1024);
            let (m, _) = iommu.map(addr, len).unwrap();
            // translation preserves the page offset at both ends
            let (h0, _) = iommu.translate(m.iova).unwrap();
            assert_eq!(h0 % 4096, 0, "seed {seed}: iova base maps to page base");
            let (hl, _) = iommu.translate(m.iova + len - 1).unwrap();
            // host pages of one mapping are contiguous, so the window is
            // linear: last byte maps exactly (len-1) past the first
            assert_eq!(hl - h0, len - 1, "seed {seed}: contiguous iova window");
            maps.push(m);
        }
        let pages: u64 = maps.iter().map(|m| m.pages).sum();
        assert_eq!(iommu.live_pages() as u64, pages, "seed {seed}");
        for m in maps.drain(..) {
            iommu.unmap(&m);
        }
        assert_eq!(iommu.live_pages(), 0, "seed {seed}");
    }
}

#[test]
fn prop_trace_regions_sum_to_total() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x77AC);
        let mut trace = Trace::new();
        let classes = RegionClass::ALL;
        let mut start = 0u64;
        for _ in 0..100 {
            let c = classes[rng.below(4) as usize];
            let dur = rng.below(10_000);
            trace.record(c, Cycles(start), Cycles(dur), "x");
            start += dur;
        }
        let sum: u64 = classes.iter().map(|&c| trace.total(c).0).sum();
        assert_eq!(sum, trace.grand_total().0, "seed {seed}");
        let share_sum: f64 = classes.iter().map(|&c| trace.share(c)).sum();
        assert!((share_sum - 1.0).abs() < 1e-9 || trace.grand_total().0 == 0);
    }
}

#[test]
fn prop_dispatch_total_and_deterministic() {
    let p = DispatchPolicy::default();
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xD15);
        for _ in 0..100 {
            let m = 1 + rng.below(4096) as usize;
            let n = 1 + rng.below(4096) as usize;
            let k = 1 + rng.below(4096) as usize;
            // total: never panics; deterministic: same answer twice
            assert_eq!(p.gemm(m, n, k), p.gemm(m, n, k));
            assert_eq!(p.gemv(m, n), p.gemv(m, n));
        }
    }
}

#[test]
fn prop_packed_gemm_equals_naive_random_shapes() {
    for seed in 0..25 {
        let mut rng = Rng::new(seed ^ 0x6E44);
        let m = 1 + rng.below(96) as usize;
        let n = 1 + rng.below(96) as usize;
        let k = 1 + rng.below(96) as usize;
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        let c0 = rng.normal_vec(m * n);
        let alpha = rng.next_normal();
        let beta = rng.next_normal();
        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        host::naive_gemm(m, n, k, alpha, &a, &b, beta, &mut c1);
        host::gemm(m, n, k, alpha, &a, &b, beta, &mut c2);
        let err = c1
            .iter()
            .zip(c2.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-10, "seed {seed} ({m},{n},{k}): err {err}");
    }
}

#[test]
fn prop_transpose_involution() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x7A45);
        let r = 1 + rng.below(32) as usize;
        let c = 1 + rng.below(32) as usize;
        let x = rng.normal_vec(r * c);
        let xt = host::materialize_op(&x, r, c, hero_blas::blas::Transpose::Yes);
        let xtt = host::materialize_op(&xt, c, r, hero_blas::blas::Transpose::Yes);
        assert_eq!(x, xtt, "seed {seed}");
    }
}
