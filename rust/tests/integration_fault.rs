//! Fault injection and recovery end-to-end: injected cluster failures
//! must change *placement and accounting*, never numerics.
//!
//! Pins the ISSUE-7 acceptance criteria: with any single cluster failing
//! persistently, every request completes with a checksum bit-identical
//! to the fault-free run (by retry on a healthy cluster, or by the host
//! BLAS fallback with `degraded: true`); quarantined clusters stop
//! receiving routes; recovery invalidates the failed cluster's resident
//! operand-cache bytes; and no pins leak across any of it.

mod common;

use common::artifacts_dir;
use hero_blas::config::{DispatchMode, FaultConfig, PlatformConfig};
use hero_blas::sched::{
    ChainRequest, GemmOutcome, GemmRequest, GemvRequest, JobPayload, Priority,
    Scheduler,
};

/// Two-cluster platform with batching linger off (determinism) and the
/// operand cache on (so recovery has resident bytes to invalidate).
fn base_cfg() -> PlatformConfig {
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = 2;
    cfg.sched.queue_capacity = 64;
    cfg.sched.batch_window_ms = 0;
    cfg.sched.cache.cache_frac = 0.4;
    cfg
}

/// The mixed device-path workload every scenario replays: gemm (cold and
/// warm-B), gemv and chains, all `DeviceOnly` so the device path is
/// genuinely attempted.
fn workload() -> Vec<JobPayload> {
    let mut jobs = Vec::new();
    for seed in 0..4u64 {
        jobs.push(JobPayload::Gemm(GemmRequest {
            n: 96,
            mode: DispatchMode::DeviceOnly,
            seed,
            b_seed: if seed % 2 == 0 { Some(7) } else { None },
        }));
    }
    for seed in 0..2u64 {
        jobs.push(JobPayload::Gemv(GemvRequest {
            m: 64,
            n: 96,
            mode: DispatchMode::DeviceOnly,
            seed,
        }));
    }
    for seed in 0..2u64 {
        jobs.push(JobPayload::Chain(ChainRequest {
            m: 48,
            dims: vec![96, 64, 32],
            mode: DispatchMode::DeviceOnly,
            seed,
            b_seeds: vec![Some(7), None],
            chained: true,
        }));
    }
    jobs
}

/// Submit the whole workload concurrently (so both workers pull jobs)
/// and collect outcomes in submission order.
fn run_workload(sched: &Scheduler, jobs: Vec<JobPayload>) -> Vec<GemmOutcome> {
    let subs: Vec<_> = jobs
        .into_iter()
        .map(|p| sched.submit(Priority::Normal, p).expect("submit"))
        .collect();
    subs.into_iter()
        .map(|s| {
            s.result
                .recv_timeout(std::time::Duration::from_secs(300))
                .expect("reply")
                .expect("outcome")
        })
        .collect()
}

fn checksums(outcomes: &[GemmOutcome]) -> Vec<f64> {
    outcomes.iter().map(|o| o.checksum).collect()
}

/// Cluster 0 failing persistently at one seam: every request still
/// completes, bit-identical to the fault-free run, via retry on the
/// healthy cluster.
#[test]
fn retried_results_are_bit_identical_to_fault_free() {
    let baseline_sched = Scheduler::new(&base_cfg(), &artifacts_dir()).unwrap();
    let baseline = run_workload(&baseline_sched, workload());
    baseline_sched.shutdown();
    assert!(baseline.iter().all(|o| !o.degraded && o.attempts == 0));

    // one scenario per injected seam: staging/DMA, mailbox hang
    // (deadline trip), compute poison
    for (staging, mailbox, poison) in
        [(1.0, 0.0, 0.0), (0.0, 1.0, 0.0), (0.0, 0.0, 1.0)]
    {
        let mut cfg = base_cfg();
        cfg.sched.fault = FaultConfig {
            enabled: true,
            seed: 11,
            staging_rate: staging,
            mailbox_rate: mailbox,
            poison_rate: poison,
            target_cluster: 0,
            deadline_factor: 4.0,
            max_attempts: 3,
            backoff_base_ms: 1,
            quarantine_threshold: 100, // keep quarantine out of this test
            probe_interval: 16,
        };
        let sched = Scheduler::new(&cfg, &artifacts_dir()).unwrap();
        let outcomes = run_workload(&sched, workload());
        assert_eq!(
            checksums(&outcomes),
            checksums(&baseline),
            "seam ({staging},{mailbox},{poison}): recovered checksums \
             must be BIT-identical to the fault-free run"
        );
        // cluster 1 is healthy and never excluded, so recovery is always
        // a retry — the device served every reply
        for o in &outcomes {
            assert!(!o.degraded, "healthy cluster present: no fallback");
            if o.attempts > 0 {
                assert_eq!(o.cluster, 1, "retry must land on the healthy cluster");
            }
        }
        let m = sched.metrics();
        assert!(m.faults_injected >= 1, "cluster 0 ran at least one launch");
        assert!(m.retries >= 1);
        assert_eq!(m.failed, 0);
        assert_eq!(m.host_fallbacks, 0);
        assert_eq!(m.completed, m.submitted);
        assert_eq!(m.pin_leaks, 0, "recovery leaked operand-cache pins");
        sched.shutdown();
    }
}

/// No healthy cluster left (pool of 1, every launch faults): the job
/// falls back to the host BLAS path — checksum-identical by construction
/// — and the reply says so.
#[test]
fn host_fallback_is_bit_identical_and_degraded() {
    let mut clean = base_cfg();
    clean.sched.pool_clusters = 1;
    let baseline_sched = Scheduler::new(&clean, &artifacts_dir()).unwrap();
    let baseline = run_workload(&baseline_sched, workload());
    baseline_sched.shutdown();

    let mut cfg = clean.clone();
    cfg.sched.fault = FaultConfig {
        enabled: true,
        seed: 3,
        staging_rate: 1.0,
        mailbox_rate: 0.0,
        poison_rate: 0.0,
        target_cluster: -1,
        deadline_factor: 4.0,
        max_attempts: 3,
        backoff_base_ms: 1,
        quarantine_threshold: 100,
        probe_interval: 16,
    };
    let sched = Scheduler::new(&cfg, &artifacts_dir()).unwrap();
    let outcomes = run_workload(&sched, workload());
    assert_eq!(
        checksums(&outcomes),
        checksums(&baseline),
        "host-fallback checksums must be BIT-identical to the device run"
    );
    for o in &outcomes {
        assert!(o.degraded, "every device attempt faulted: must degrade");
        assert!(o.attempts >= 1, "the failed attempt count travels on the reply");
    }
    let m = sched.metrics();
    assert_eq!(m.host_fallbacks, outcomes.len() as u64);
    assert!(m.faults_injected >= outcomes.len() as u64);
    assert_eq!(m.failed, 0);
    assert_eq!(m.completed, m.submitted);
    assert_eq!(m.pin_leaks, 0);
    sched.shutdown();
}

/// A cluster that keeps faulting is quarantined: the router stops
/// sending it work, and with a huge probe interval it stays benched
/// while the healthy cluster serves everything cleanly.
#[test]
fn quarantined_cluster_stops_receiving_routes() {
    let mut cfg = base_cfg();
    cfg.sched.fault = FaultConfig {
        enabled: true,
        seed: 5,
        staging_rate: 1.0,
        mailbox_rate: 0.0,
        poison_rate: 0.0,
        target_cluster: 0,
        deadline_factor: 4.0,
        max_attempts: 3,
        backoff_base_ms: 1,
        quarantine_threshold: 2,
        probe_interval: 1_000_000, // no re-admission inside this test
    };
    let sched = Scheduler::new(&cfg, &artifacts_dir()).unwrap();

    // feed waves of work until cluster 0 has faulted its way into
    // quarantine (every launch it runs faults, so this converges fast)
    let mut waves = 0;
    while !sched.is_quarantined(0) && waves < 32 {
        let outcomes = run_workload(&sched, workload());
        assert!(outcomes.iter().all(|o| !o.degraded));
        waves += 1;
    }
    assert!(sched.is_quarantined(0), "cluster 0 never quarantined");
    assert!(!sched.is_quarantined(1));
    let before = sched.metrics();
    assert!(before.quarantined >= 1);

    // post-quarantine: everything routes to (and completes on) cluster 1
    // and no further faults fire
    let outcomes = run_workload(&sched, workload());
    for o in &outcomes {
        assert!(!o.degraded);
        assert_eq!(o.attempts, 0, "quarantined cluster must not be routed");
        assert_eq!(o.cluster, 1);
    }
    let after = sched.metrics();
    assert_eq!(after.faults_injected, before.faults_injected);
    assert_eq!(after.failed, 0);
    assert_eq!(after.pin_leaks, 0);
    sched.shutdown();
}

/// A retried-then-degraded request still carries a coherent span
/// record: the five telescoping stages sum exactly to the reported
/// total latency, the wall time burnt by the failed device attempts
/// rides alongside as `retry_us` (outside the telescoping sum), and
/// once every reply is out the inflight gauges are drained.
#[test]
fn fault_path_spans_reconcile_and_inflight_drains() {
    let mut cfg = base_cfg();
    cfg.sched.pool_clusters = 1;
    cfg.sched.fault = FaultConfig {
        enabled: true,
        seed: 13,
        staging_rate: 1.0,
        mailbox_rate: 0.0,
        poison_rate: 0.0,
        target_cluster: -1,
        deadline_factor: 4.0,
        max_attempts: 2, // at least one failed attempt, then host fallback
        backoff_base_ms: 1,
        quarantine_threshold: 100,
        probe_interval: 16,
    };
    let sched = Scheduler::new(&cfg, &artifacts_dir()).unwrap();
    let outcomes = run_workload(&sched, workload());
    for o in &outcomes {
        assert!(o.degraded, "every device attempt faulted: must degrade");
        assert!(o.attempts >= 1);
        assert!(
            o.spans.retry_us > 0,
            "failed device attempts must surface as retry_us"
        );
        let stage_sum: u64 = o.spans.stages().iter().map(|(_, us)| *us).sum();
        assert_eq!(
            stage_sum, o.spans.total_us,
            "the five stages must telescope to the total on the fault path"
        );
    }
    let m = sched.metrics();
    assert_eq!(m.host_fallbacks, outcomes.len() as u64);
    assert!(m.retries >= outcomes.len() as u64);
    for c in &m.clusters {
        assert_eq!(c.inflight, 0, "inflight gauge must drain after fallback");
    }
    assert_eq!(m.pin_leaks, 0);
    sched.shutdown();
}

/// Recovery invalidates the failed cluster's resident operand-cache
/// entries: a warm B staged before the fault is evicted, and the counter
/// reports the released bytes.
#[test]
fn fault_recovery_invalidates_resident_cache_bytes() {
    let mut cfg = base_cfg();
    cfg.sched.pool_clusters = 1;
    cfg.sched.fault = FaultConfig {
        enabled: true,
        seed: 9,
        staging_rate: 1.0,
        mailbox_rate: 0.0,
        poison_rate: 0.0,
        target_cluster: -1,
        deadline_factor: 4.0,
        max_attempts: 1, // straight to the host fallback
        backoff_base_ms: 1,
        quarantine_threshold: 100,
        probe_interval: 16,
    };
    let sched = Scheduler::new(&cfg, &artifacts_dir()).unwrap();

    // staging caches the shared-B operand, then the injected DMA fault
    // abandons the batch — recovery must evict that resident entry
    let outcomes = run_workload(
        &sched,
        vec![JobPayload::Gemm(GemmRequest {
            n: 96,
            mode: DispatchMode::DeviceOnly,
            seed: 1,
            b_seed: Some(7),
        })],
    );
    assert!(outcomes[0].degraded);
    let m = sched.metrics();
    assert_eq!(m.host_fallbacks, 1);
    let b_bytes = (96 * 96 * std::mem::size_of::<f64>()) as u64;
    assert!(
        m.cache_invalidated_bytes >= b_bytes,
        "expected >= {} invalidated bytes, got {}",
        b_bytes,
        m.cache_invalidated_bytes
    );
    assert_eq!(m.pin_leaks, 0);
    sched.shutdown();
}
