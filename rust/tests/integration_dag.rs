//! DAG executor end-to-end: fan-out/fan-in graphs must change *data
//! movement*, never numerics.
//!
//! Pins the ISSUE-10 acceptance criteria: a linear DAG is bit-identical
//! to the equivalent chain WITH an identical charge sequence, a fan-out
//! trunk is staged exactly once and matches the per-op oracle
//! bit-for-bit, cancel-mid-DAG releases every pin, a fused
//! cross-request splice reproduces the combined graph's checksum, and
//! malformed/oversized graphs fail fast at validation with the
//! offending node named.

mod common;

use std::time::Duration;

use common::artifacts_dir;
use hero_blas::blas::{ChainLink, DagNode, DispatchPolicy, HeroBlas};
use hero_blas::config::{DispatchMode, PlatformConfig};
use hero_blas::dag::{linear_gemm_shape, DagNodeShape, DagOp, DagShape};
use hero_blas::sched::{
    ChainRequest, DagRequest, JobPayload, Priority, Scheduler,
};
use hero_blas::util::rng::Rng;

fn session_with(cfg: PlatformConfig, mode: DispatchMode) -> HeroBlas {
    HeroBlas::new(cfg, &artifacts_dir(), DispatchPolicy::with_mode(mode))
        .expect("session construction")
}

fn gemm(src: Option<usize>, n: usize) -> DagNodeShape {
    DagNodeShape { op: DagOp::Gemm, src, src2: None, n, bias: false, relu: false }
}

fn run(sched: &Scheduler, payload: JobPayload) -> hero_blas::sched::GemmOutcome {
    sched
        .submit(Priority::Normal, payload)
        .expect("submit")
        .result
        .recv_timeout(Duration::from_secs(300))
        .expect("reply")
        .expect("outcome")
}

#[test]
fn linear_dag_matches_chain_bit_for_bit_with_identical_charges() {
    // fresh scheduler per submission: the operand cache is
    // content-addressed, so running both on one pool would hand the
    // second run warm weights and skew its charge sequence
    let cfg = || {
        let mut cfg = PlatformConfig::default();
        cfg.sched.pool_clusters = 1;
        cfg.sched.batch_window_ms = 0;
        cfg
    };
    let chain_sched = Scheduler::new(&cfg(), &artifacts_dir()).unwrap();
    let dag_sched = Scheduler::new(&cfg(), &artifacts_dir()).unwrap();

    let chain = ChainRequest {
        m: 48,
        dims: vec![96, 64, 32],
        mode: DispatchMode::DeviceOnly,
        seed: 11,
        b_seeds: vec![Some(7), Some(8)],
        chained: true,
    };
    let dag = DagRequest {
        shape: linear_gemm_shape(48, &[96, 64, 32]),
        mode: DispatchMode::DeviceOnly,
        seed: 11,
        b_seeds: vec![Some(7), Some(8)],
        publish_key: None,
        input_key: None,
    };

    let c = run(&chain_sched, JobPayload::Chain(chain));
    let d = run(&dag_sched, JobPayload::Dag(dag));
    assert_eq!(c.op, "chain");
    assert_eq!(d.op, "dag");
    assert_eq!((c.m, c.n), (d.m, d.n));
    assert_eq!(
        c.checksum, d.checksum,
        "linear dag must be BIT-identical to the equivalent chain"
    );
    // the lowering contract: a linear single-consumer DAG produces the
    // SAME virtual-time charge sequence as the chain path
    assert_eq!(c.data_copy_ms, d.data_copy_ms, "data-copy charges diverged");
    assert_eq!(c.fork_join_ms, d.fork_join_ms, "fork-join charges diverged");
    assert_eq!(c.compute_ms, d.compute_ms, "compute charges diverged");
    assert_eq!(c.host_compute_ms, d.host_compute_ms);

    let m = dag_sched.metrics();
    assert_eq!(m.dags, 1, "one dag submission counted");
    assert_eq!(m.dag_nodes, 2, "both nodes counted");
    assert!(m.dag_bytes_elided > 0, "interior edge must elide bytes");
    assert_eq!(m.pin_leaks, 0);
    chain_sched.shutdown();
    dag_sched.shutdown();
}

#[test]
fn fan_out_trunk_stages_once_and_matches_per_op_oracle() {
    let (m, d0, h, n) = (32usize, 48usize, 40usize, 24usize);
    let mut rng = Rng::new(0xF0);
    let x = rng.normal_vec(m * d0);
    let w0 = rng.normal_vec(d0 * h);
    let b0 = rng.normal_vec(h);
    let w1 = rng.normal_vec(h * n);
    let w2 = rng.normal_vec(h * n);

    // per-op oracle: the trunk computed ONCE (bias+relu epilogues),
    // then each head as its own offload from the host copy
    let mut per_op = session_with(PlatformConfig::default(), DispatchMode::DeviceOnly);
    let mut trunk = vec![0.0; m * h];
    per_op
        .chain(
            m,
            &x,
            &[ChainLink { b: &w0, dims: (d0, h), bias: Some(&b0), relu: true }],
            &mut trunk,
        )
        .unwrap();
    let head = |blas: &mut HeroBlas, w: &[f64]| {
        let mut c = vec![0.0; m * n];
        blas.gemm(
            hero_blas::blas::Transpose::No,
            hero_blas::blas::Transpose::No,
            1.0,
            &trunk,
            (m, h),
            w,
            (h, n),
            0.0,
            &mut c,
            (m, n),
        )
        .unwrap();
        c
    };
    let want1 = head(&mut per_op, &w1);
    let want2 = head(&mut per_op, &w2);

    // the same graph as ONE dag submission: both heads are sinks, so
    // the trunk has two consumers and is promoted exactly once
    let shape = DagShape {
        m,
        d0,
        nodes: vec![
            DagNodeShape {
                op: DagOp::Gemm,
                src: None,
                src2: None,
                n: h,
                bias: true,
                relu: true,
            },
            gemm(Some(0), n),
            gemm(Some(0), n),
        ],
    };
    let specs = vec![
        DagNode { b: Some(&w0), bias: Some(&b0) },
        DagNode { b: Some(&w1), bias: None },
        DagNode { b: Some(&w2), bias: None },
    ];
    let mut dev = session_with(PlatformConfig::default(), DispatchMode::DeviceOnly);
    let (mut out1, mut out2) = (vec![0.0; m * n], vec![0.0; m * n]);
    {
        let mut refs: Vec<&mut [f64]> = vec![&mut out1, &mut out2];
        dev.dag(&shape, &x, &specs, &mut refs).unwrap();
    }
    let dm = dev.metrics();

    assert_eq!(out1, want1, "fan-out head 1 must match the per-op oracle");
    assert_eq!(out2, want2, "fan-out head 2 must match the per-op oracle");
    assert_eq!(dm.offloads, 1, "a dag is ONE fork-join");
    // trunk promoted once (the skipped map-from) + two consuming edges
    // (both skipped map-tos): exactly three trunk transfers elided
    assert_eq!(dm.dag_bytes_elided, 3 * (m * h * 8) as u64);
    assert_eq!(dev.engine.opcache.total_pins(), 0);
    assert_eq!(dev.engine.device.dram.stats().bytes_in_use, 0);
}

#[test]
fn fan_in_diamond_matches_the_host_path_bit_for_bit() {
    let (m, d0, h, n) = (24usize, 32usize, 28usize, 16usize);
    let mut rng = Rng::new(0xD1);
    let x = rng.normal_vec(m * d0);
    let w0 = rng.normal_vec(d0 * h);
    let w1 = rng.normal_vec(h * n);
    let w2 = rng.normal_vec(h * n);

    // diamond: one trunk, two branch heads, one axpy fan-in sink
    let shape = DagShape {
        m,
        d0,
        nodes: vec![
            gemm(None, h),
            gemm(Some(0), n),
            gemm(Some(0), n),
            DagNodeShape {
                op: DagOp::Axpy,
                src: Some(1),
                src2: Some(2),
                n: 0,
                bias: false,
                relu: false,
            },
        ],
    };
    let specs = vec![
        DagNode { b: Some(&w0), bias: None },
        DagNode { b: Some(&w1), bias: None },
        DagNode { b: Some(&w2), bias: None },
        DagNode { b: None, bias: None },
    ];

    let mut host = session_with(PlatformConfig::default(), DispatchMode::HostOnly);
    let mut want = vec![0.0; m * n];
    {
        let mut refs: Vec<&mut [f64]> = vec![&mut want];
        host.dag(&shape, &x, &specs, &mut refs).unwrap();
    }
    let mut dev = session_with(PlatformConfig::default(), DispatchMode::DeviceOnly);
    let mut got = vec![0.0; m * n];
    {
        let mut refs: Vec<&mut [f64]> = vec![&mut got];
        dev.dag(&shape, &x, &specs, &mut refs).unwrap();
    }
    assert_eq!(got, want, "device diamond must match the host path exactly");
    assert!(dev.metrics().dag_bytes_elided > 0);
    assert_eq!(dev.engine.opcache.total_pins(), 0);
}

#[test]
fn cancelled_dag_releases_pins_and_device_memory() {
    // cache ON so staged weights pin operand-cache entries — the leak
    // the abandon path must not allow
    let mut cfg = PlatformConfig::default();
    cfg.sched.cache.cache_frac = 0.4;
    cfg.sched.cache.cache_max_entries = 32;
    let mut blas = session_with(cfg, DispatchMode::DeviceOnly);

    let (m, d0, h, n) = (32usize, 48usize, 40usize, 24usize);
    let mut rng = Rng::new(0xCA);
    let x = rng.normal_vec(m * d0);
    let w0 = rng.normal_vec(d0 * h);
    let w1 = rng.normal_vec(h * n);
    let w2 = rng.normal_vec(h * n);
    let shape = DagShape {
        m,
        d0,
        nodes: vec![gemm(None, h), gemm(Some(0), n), gemm(Some(0), n)],
    };
    let specs = vec![
        DagNode { b: Some(&w0), bias: None },
        DagNode { b: Some(&w1), bias: None },
        DagNode { b: Some(&w2), bias: None },
    ];

    let staged = blas.dag_stage(&shape, &x, &specs).unwrap();
    assert!(
        blas.engine.opcache.total_pins() > 0,
        "staged dag must pin its cached operands"
    );
    assert!(blas.engine.device.dram.stats().bytes_in_use > 0);

    // REPLY_TIMEOUT fired mid-DAG: abandon must release every pin and
    // every map(alloc:) output
    blas.dag_abandon(staged);
    assert_eq!(blas.engine.opcache.total_pins(), 0, "stranded cache pins");
    let resident = blas.engine.opcache.bytes_resident();
    assert_eq!(
        blas.engine.device.dram.stats().bytes_in_use,
        resident,
        "abandoned dag stranded non-cache device allocations"
    );

    // the session stays fully usable: the same dag runs to completion
    let (mut o1, mut o2) = (vec![0.0; m * n], vec![0.0; m * n]);
    {
        let mut refs: Vec<&mut [f64]> = vec![&mut o1, &mut o2];
        blas.dag(&shape, &x, &specs, &mut refs).unwrap();
    }
    assert_eq!(blas.engine.opcache.total_pins(), 0);
}

#[test]
fn cancel_mid_dag_leaks_no_pins_through_the_scheduler() {
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = 1;
    cfg.sched.batch_window_ms = 0;
    cfg.sched.cache.cache_frac = 0.4;
    let sched = Scheduler::new(&cfg, &artifacts_dir()).unwrap();

    let dag = |seed: u64| DagRequest {
        shape: DagShape {
            m: 48,
            d0: 64,
            nodes: vec![gemm(None, 64), gemm(Some(0), 32), gemm(Some(0), 32)],
        },
        mode: DispatchMode::DeviceOnly,
        seed,
        b_seeds: vec![Some(1), Some(2), Some(3)],
        publish_key: None,
        input_key: None,
    };
    // cancel a burst immediately after submit: whichever seam each job
    // reaches (dequeue, post-stage), no pin may leak
    for s in 0..4 {
        let sub = sched
            .submit(Priority::Normal, JobPayload::Dag(dag(s)))
            .expect("submit");
        sub.cancel.cancel();
    }
    // a follow-up served to completion proves the worker drained past
    // the cancelled jobs with a clean cache
    let o = run(&sched, JobPayload::Dag(dag(99)));
    assert_eq!(o.op, "dag");
    let m = sched.metrics();
    assert_eq!(m.pin_leaks, 0, "cancel-mid-dag leaked operand pins");
    assert_eq!(m.failed, 0);
    sched.shutdown();
}

#[test]
fn fused_cross_request_matches_the_combined_dag() {
    let cfg = || {
        let mut cfg = PlatformConfig::default();
        cfg.sched.pool_clusters = 1;
        cfg.sched.batch_window_ms = 0;
        cfg.sched.dag.fuse_window_ms = 10_000;
        cfg
    };
    let (m, d0, n1, n2) = (32usize, 64usize, 48usize, 24usize);

    // the combined oracle on its own pool: both layers in one graph
    let oracle_sched = Scheduler::new(&cfg(), &artifacts_dir()).unwrap();
    let combined = DagRequest {
        shape: DagShape { m, d0, nodes: vec![gemm(None, n1), gemm(Some(0), n2)] },
        mode: DispatchMode::DeviceOnly,
        seed: 5,
        b_seeds: vec![Some(41), Some(42)],
        publish_key: None,
        input_key: None,
    };
    let want = run(&oracle_sched, JobPayload::Dag(combined));
    oracle_sched.shutdown();

    // request A publishes its sink; request B splices onto it.  B's own
    // seed draws nothing (its input IS A's resident output) and its
    // weights come from the same b_seed stream as the oracle's layer 2.
    let sched = Scheduler::new(&cfg(), &artifacts_dir()).unwrap();
    let a = DagRequest {
        shape: DagShape { m, d0, nodes: vec![gemm(None, n1)] },
        mode: DispatchMode::DeviceOnly,
        seed: 5,
        b_seeds: vec![Some(41)],
        publish_key: Some(0xFEED),
        input_key: None,
    };
    let b = DagRequest {
        shape: DagShape { m, d0: n1, nodes: vec![gemm(None, n2)] },
        mode: DispatchMode::DeviceOnly,
        seed: 999,
        b_seeds: vec![Some(42)],
        publish_key: None,
        input_key: Some(0xFEED),
    };
    let oa = run(&sched, JobPayload::Dag(a));
    assert_eq!(oa.op, "dag");
    let ob = run(&sched, JobPayload::Dag(b));
    assert_eq!((ob.m, ob.n), (want.m, want.n));
    assert_eq!(
        ob.checksum, want.checksum,
        "fused splice must reproduce the combined graph's checksum"
    );
    let ms = sched.metrics();
    assert_eq!(ms.dag_fused_requests, 1, "exactly one request fused");
    assert_eq!(ms.pin_leaks, 0);
    sched.shutdown();
}

#[test]
fn invalid_dags_fail_fast_with_the_node_named() {
    let mut cfg = PlatformConfig::default();
    cfg.sched.pool_clusters = 4; // small slices: ~16 MiB each
    cfg.sched.queue_capacity = 8;
    cfg.sched.dag.fuse_window_ms = 0; // fusion disabled
    let sched = Scheduler::new(&cfg, &artifacts_dir()).unwrap();

    let req = |shape: DagShape| {
        let n = shape.nodes.len();
        DagRequest {
            shape,
            mode: DispatchMode::DeviceOnly,
            seed: 1,
            b_seeds: vec![None; n],
            publish_key: None,
            input_key: None,
        }
    };

    // too many nodes for [sched.dag] max_nodes
    let long = linear_gemm_shape(16, &vec![16usize; 18]);
    let err = sched.validate_dag(&req(long)).unwrap_err();
    assert!(err.contains("max_nodes"), "unhelpful node-bound error: {err}");

    // a backward edge is a cycle, named by node
    let cyclic = DagShape {
        m: 16,
        d0: 16,
        nodes: vec![DagNodeShape {
            op: DagOp::Gemm,
            src: Some(0),
            src2: None,
            n: 16,
            bias: false,
            relu: false,
        }],
    };
    let err = sched.validate_dag(&req(cyclic)).unwrap_err();
    assert!(err.contains("node 0"), "cycle error must name the node: {err}");
    assert!(err.contains("cycle"), "unhelpful cycle error: {err}");

    // fan-in width mismatch, named by node
    let lopsided = DagShape {
        m: 16,
        d0: 16,
        nodes: vec![
            gemm(None, 16),
            gemm(None, 8),
            DagNodeShape {
                op: DagOp::Axpy,
                src: Some(0),
                src2: Some(1),
                n: 0,
                bias: false,
                relu: false,
            },
        ],
    };
    let err = sched.validate_dag(&req(lopsided)).unwrap_err();
    assert!(err.contains("node 2"), "fan-in error must name the node: {err}");

    // a footprint no cluster slice can stage
    let big = linear_gemm_shape(640, &vec![640usize; 7]);
    let err = sched.validate_dag(&req(big)).unwrap_err();
    assert!(err.contains("slice"), "unhelpful capacity error: {err}");

    // b_seeds arity
    let mut wrong = req(linear_gemm_shape(16, &[16, 16]));
    wrong.b_seeds = vec![None, None];
    let err = sched.validate_dag(&wrong).unwrap_err();
    assert!(err.contains("b_seeds"), "unhelpful arity error: {err}");

    // fusion keys while the window is disabled
    let mut fused = req(linear_gemm_shape(16, &[16, 16]));
    fused.publish_key = Some(7);
    let err = sched.validate_dag(&fused).unwrap_err();
    assert!(err.contains("fuse_window_ms"), "unhelpful window error: {err}");

    // a well-formed dag passes the same gate
    let ok = req(linear_gemm_shape(64, &[64, 64]));
    assert!(sched.validate_dag(&ok).is_ok());
    sched.shutdown();
}
