//! End-to-end GEMM: device offload numerics vs host kernels, across
//! shapes, coefficients, transposes, dtypes and both offload paths.

mod common;

use common::{max_abs_diff, session};
use hero_blas::blas::host;
use hero_blas::blas::{Transpose};
use hero_blas::config::DispatchMode;
use hero_blas::npy::NdArray;
use hero_blas::soc::trace::RegionClass;
use hero_blas::util::rng::Rng;

fn rand(rng: &mut Rng, n: usize) -> Vec<f64> {
    rng.normal_vec(n)
}

/// Device GEMM == naive host GEMM for a batch of shapes, including
/// non-tile-multiples (exercises the padding path) and rectangular cases.
#[test]
fn device_gemm_matches_host_many_shapes() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(42);
    for &(m, n, k) in &[
        (1usize, 1usize, 1usize),
        (3, 5, 2),
        (16, 16, 16),
        (64, 64, 64),
        (65, 63, 64),   // straddles tile boundaries
        (100, 50, 75),
        (128, 128, 128),
        (130, 140, 150),
    ] {
        let a = rand(&mut rng, m * k);
        let b = rand(&mut rng, k * n);
        let c0 = rand(&mut rng, m * n);
        let mut c_dev = c0.clone();
        blas.gemm(
            Transpose::No, Transpose::No, 1.25, &a, (m, k), &b, (k, n),
            -0.5, &mut c_dev, (m, n),
        )
        .unwrap();
        let mut c_ref = c0.clone();
        host::naive_gemm(m, n, k, 1.25, &a, &b, -0.5, &mut c_ref);
        let err = max_abs_diff(&c_dev, &c_ref);
        assert!(err < 1e-10, "({m},{n},{k}): err {err}");
    }
}

#[test]
fn device_gemm_transposes() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(7);
    let (m, n, k) = (40, 30, 20);
    for &(ta, tb) in &[
        (Transpose::No, Transpose::Yes),
        (Transpose::Yes, Transpose::No),
        (Transpose::Yes, Transpose::Yes),
    ] {
        let a_dims = if ta.is_trans() { (k, m) } else { (m, k) };
        let b_dims = if tb.is_trans() { (n, k) } else { (k, n) };
        let a = rand(&mut rng, a_dims.0 * a_dims.1);
        let b = rand(&mut rng, b_dims.0 * b_dims.1);
        let mut c_dev = vec![0.0; m * n];
        blas.gemm(ta, tb, 1.0, &a, a_dims, &b, b_dims, 0.0, &mut c_dev, (m, n))
            .unwrap();
        // reference via materialized ops
        let a_op = host::materialize_op(&a, a_dims.0, a_dims.1, ta);
        let b_op = host::materialize_op(&b, b_dims.0, b_dims.1, tb);
        let mut c_ref = vec![0.0; m * n];
        host::naive_gemm(m, n, k, 1.0, &a_op, &b_op, 0.0, &mut c_ref);
        assert!(max_abs_diff(&c_dev, &c_ref) < 1e-10, "{ta:?} {tb:?}");
    }
}

#[test]
fn zero_copy_equals_copy_numerics() {
    let mut copy = session(DispatchMode::DeviceOnly);
    let mut zc = session(DispatchMode::DeviceZeroCopy);
    let mut rng = Rng::new(99);
    let n = 96;
    let a = NdArray::<f64>::randn(&mut rng, &[n, n]);
    let b = NdArray::<f64>::randn(&mut rng, &[n, n]);
    let c1 = a.matmul(&b, &mut copy).unwrap();
    let c2 = a.matmul(&b, &mut zc).unwrap();
    assert_eq!(c1.data(), c2.data(), "zero-copy must be bit-identical");
    // but their copy-region accounting must differ (PTEs vs memcpys)
    assert!(zc.engine.metrics.iommu_pages_mapped > 0);
    assert_eq!(zc.engine.metrics.bytes_to_device, 0);
    assert!(copy.engine.metrics.bytes_to_device > 0);
}

#[test]
fn f32_device_gemm_matches_host() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(3);
    let n = 70;
    let a = NdArray::<f32>::randn(&mut rng, &[n, n]);
    let b = NdArray::<f32>::randn(&mut rng, &[n, n]);
    let c = a.matmul(&b, &mut blas).unwrap();
    let mut c_ref = vec![0.0f32; n * n];
    host::naive_gemm(n, n, n, 1.0f32, a.data(), b.data(), 0.0, &mut c_ref);
    let err = c
        .data()
        .iter()
        .zip(c_ref.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 1e-3, "f32 err {err}");
}

#[test]
fn auto_dispatch_small_stays_host_large_offloads() {
    let mut blas = session(DispatchMode::Auto);
    let mut rng = Rng::new(1);

    let small = NdArray::<f64>::randn(&mut rng, &[16, 16]);
    blas.reset_run();
    small.matmul(&small, &mut blas).unwrap();
    assert_eq!(blas.engine.metrics.offloads, 0, "16x16 must stay on host");
    assert!(blas.engine.trace.total(RegionClass::HostCompute).0 > 0);

    let large = NdArray::<f64>::randn(&mut rng, &[128, 128]);
    blas.reset_run();
    large.matmul(&large, &mut blas).unwrap();
    assert_eq!(blas.engine.metrics.offloads, 1, "128x128 must offload");
    assert!(blas.engine.trace.total(RegionClass::DataCopy).0 > 0);
}

#[test]
fn offload_regions_all_present_and_sum() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(5);
    let a = NdArray::<f64>::randn(&mut rng, &[64, 64]);
    blas.reset_run();
    a.matmul(&a, &mut blas).unwrap();
    let t = &blas.engine.trace;
    let dc = t.total(RegionClass::DataCopy).0;
    let fj = t.total(RegionClass::ForkJoin).0;
    let cp = t.total(RegionClass::Compute).0;
    assert!(dc > 0 && fj > 0 && cp > 0);
    assert_eq!(t.grand_total().0, dc + fj + cp);
    // fork/join is size-independent: equals config sum
    let cfg = &blas.engine.platform.cfg.forkjoin;
    let expect_fj = cfg.openblas_entry_cycles
        + cfg.omp_entry_cycles
        + 3 * cfg.per_arg_cycles
        + cfg.doorbell_cycles
        + 2 * cfg.device_wakeup_cycles // launch wake + (none at join)
        - cfg.device_wakeup_cycles
        + cfg.doorbell_cycles
        + cfg.join_cycles
        + cfg.exit_cycles;
    assert_eq!(fj, expect_fj, "fork/join must be the configured fixed cost");
}

#[test]
fn gemm_shape_errors_rejected() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let a = vec![0.0; 12];
    let b = vec![0.0; 12];
    let mut c = vec![0.0; 9];
    // contraction mismatch
    assert!(blas
        .gemm(Transpose::No, Transpose::No, 1.0, &a, (3, 4), &b, (3, 4), 0.0, &mut c, (3, 3))
        .is_err());
    // wrong C shape
    assert!(blas
        .gemm(Transpose::No, Transpose::No, 1.0, &a, (3, 4), &b, (4, 3), 0.0, &mut c, (4, 4))
        .is_err());
}

#[test]
fn oom_mid_offload_recovers_cleanly() {
    use hero_blas::blas::{DispatchPolicy, HeroBlas};
    use hero_blas::config::PlatformConfig;

    // device DRAM too small for three 128x128 f64 buffers (384 KiB):
    // the third map_to must OOM mid-offload
    let mut cfg = PlatformConfig::default();
    cfg.memory.dev_dram_bytes = 256 * 1024;
    let mut blas = HeroBlas::new(
        cfg,
        &common::artifacts_dir(),
        DispatchPolicy::with_mode(DispatchMode::DeviceOnly),
    )
    .unwrap();

    let mut rng = Rng::new(31);
    let big = NdArray::<f64>::randn(&mut rng, &[128, 128]);
    let err = big.matmul(&big, &mut blas);
    assert!(err.is_err(), "offload must fail with tiny device DRAM");
    assert!(err.unwrap_err().to_string().contains("out of memory"));

    // error path must have released everything…
    assert_eq!(blas.engine.device.dram.stats().bytes_in_use, 0);
    assert_eq!(blas.engine.datamap.live_mappings(), 0);

    // …and the session must still work for problems that fit
    let small = NdArray::<f64>::randn(&mut rng, &[64, 64]);
    let c = small.matmul(&small, &mut blas).unwrap();
    let mut c_ref = vec![0.0; 64 * 64];
    host::naive_gemm(64, 64, 64, 1.0, small.data(), small.data(), 0.0, &mut c_ref);
    assert!(max_abs_diff(c.data(), &c_ref) < 1e-10);
}

#[test]
fn spm_too_small_rejected_before_any_mapping() {
    use hero_blas::blas::{DispatchPolicy, HeroBlas};
    use hero_blas::config::PlatformConfig;

    let mut cfg = PlatformConfig::default();
    cfg.memory.l1_spm_bytes = 100 * 1024; // < 96 KiB tile set + validate floor
    let mut blas = HeroBlas::new(
        cfg,
        &common::artifacts_dir(),
        DispatchPolicy::with_mode(DispatchMode::DeviceOnly),
    )
    .unwrap();
    // f64 64x64x64 tile set is 96 KiB -> fits 100 KiB; shrink further via
    // a direct check: the guard must fire for a hypothetical bigger set.
    assert!(blas.engine.platform.cluster.fits_spm(96 * 1024));
    assert!(!blas.engine.platform.cluster.fits_spm(128 * 1024));
    // sanity: gemm still works at this SPM size
    let mut rng = Rng::new(32);
    let a = NdArray::<f64>::randn(&mut rng, &[64, 64]);
    a.matmul(&a, &mut blas).unwrap();
}

#[test]
fn repeated_offloads_do_not_leak_device_memory() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(8);
    let a = NdArray::<f64>::randn(&mut rng, &[64, 64]);
    for _ in 0..5 {
        a.matmul(&a, &mut blas).unwrap();
    }
    let stats = blas.engine.device.dram.stats();
    assert_eq!(stats.bytes_in_use, 0, "all offload buffers must be freed");
    assert_eq!(stats.allocs, stats.frees);
    assert_eq!(blas.engine.datamap.live_mappings(), 0);
}
