//! NumPy-frontend end-to-end: the paper's "user application" surface.

mod common;

use common::session;
use hero_blas::config::DispatchMode;
use hero_blas::npy::NdArray;
use hero_blas::util::rng::Rng;

#[test]
fn matmul_chain_mixed_dispatch() {
    let mut blas = session(DispatchMode::Auto);
    let mut rng = Rng::new(1);
    // (20x30)@(30x40)@(40x10): middle sizes straddle the auto threshold
    let a = NdArray::<f64>::randn(&mut rng, &[20, 30]);
    let b = NdArray::<f64>::randn(&mut rng, &[30, 40]);
    let c = NdArray::<f64>::randn(&mut rng, &[40, 10]);
    let ab = a.matmul(&b, &mut blas).unwrap();
    let abc = ab.matmul(&c, &mut blas).unwrap();
    assert_eq!(abc.shape(), &[20, 10]);
    // reference
    let mut ab_ref = vec![0.0; 20 * 40];
    hero_blas::blas::host::naive_gemm(20, 40, 30, 1.0, a.data(), b.data(), 0.0, &mut ab_ref);
    let mut abc_ref = vec![0.0; 20 * 10];
    hero_blas::blas::host::naive_gemm(20, 10, 40, 1.0, &ab_ref, c.data(), 0.0, &mut abc_ref);
    assert!(common::max_abs_diff(abc.data(), &abc_ref) < 1e-10);
}

#[test]
fn matvec_and_vector_helpers() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(2);
    let a = NdArray::<f64>::randn(&mut rng, &[65, 30]);
    let x = NdArray::<f64>::randn(&mut rng, &[30]);
    let y = a.matvec(&x, &mut blas).unwrap();
    assert_eq!(y.shape(), &[65]);
    for i in 0..65 {
        let want: f64 = (0..30).map(|j| a.get2(i, j) * x.data()[j]).sum();
        assert!((y.data()[i] - want).abs() < 1e-10);
    }

    let v = NdArray::<f64>::linspace(1.0, 4.0, 4);
    let w = NdArray::<f64>::ones(&[4]);
    assert!((v.vdot(&w, &mut blas).unwrap() - 10.0).abs() < 1e-12);
    assert!((v.norm(&mut blas).unwrap() - 30f64.sqrt()).abs() < 1e-12);

    let mut acc = NdArray::<f64>::zeros(&[4]);
    acc.axpy_from(2.0, &v, &mut blas).unwrap();
    assert_eq!(acc.data(), &[2.0, 4.0, 6.0, 8.0]);
}

#[test]
fn transpose_composes_with_matmul() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(3);
    let a = NdArray::<f64>::randn(&mut rng, &[40, 70]);
    // gram matrix two ways: (a.t() @ a) vs gemm with trans_a
    let g1 = a.t().unwrap().matmul(&a, &mut blas).unwrap();
    let mut g2 = vec![0.0; 70 * 70];
    blas.gemm(
        hero_blas::blas::Transpose::Yes,
        hero_blas::blas::Transpose::No,
        1.0,
        a.data(),
        (40, 70),
        a.data(),
        (40, 70),
        0.0,
        &mut g2,
        (70, 70),
    )
    .unwrap();
    assert!(common::max_abs_diff(g1.data(), &g2) < 1e-10);
}

#[test]
fn shape_errors_surface_cleanly() {
    let mut blas = session(DispatchMode::HostOnly);
    let a = NdArray::<f64>::zeros(&[3, 4]);
    let b = NdArray::<f64>::zeros(&[5, 6]);
    assert!(a.matmul(&b, &mut blas).is_err());
    let v = NdArray::<f64>::zeros(&[4]);
    assert!(v.matmul(&a, &mut blas).is_err()); // 1-D lhs
    assert!(a.matvec(&NdArray::<f64>::zeros(&[3]), &mut blas).is_err());
    assert!(v.vdot(&NdArray::<f64>::zeros(&[5]), &mut blas).is_err());
    let mut y = NdArray::<f64>::zeros(&[3]);
    assert!(y.axpy_from(1.0, &v, &mut blas).is_err());
}

#[test]
fn sub_matrix_blocks_multiply_like_the_whole() {
    // block matmul identity: C = A@B == [A1; A2] @ B stacked
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(4);
    let a = NdArray::<f64>::randn(&mut rng, &[64, 48]);
    let b = NdArray::<f64>::randn(&mut rng, &[48, 32]);
    let whole = a.matmul(&b, &mut blas).unwrap();
    let top = a.slice_rows(0, 24).unwrap().matmul(&b, &mut blas).unwrap();
    let bot = a.slice_rows(24, 64).unwrap().matmul(&b, &mut blas).unwrap();
    let stacked = NdArray::vstack(&[&top, &bot]).unwrap();
    assert!(whole.max_abs_diff(&stacked) < 1e-10);
}

#[test]
fn f32_frontend_roundtrip() {
    let mut blas = session(DispatchMode::DeviceOnly);
    let mut rng = Rng::new(5);
    let a = NdArray::<f32>::randn(&mut rng, &[32, 32]);
    let e = NdArray::<f32>::eye(32);
    let c = a.matmul(&e, &mut blas).unwrap();
    assert!(c.max_abs_diff(&a) < 1e-4);
}
