//! Compile-only stand-in for the `xla` crate (xla-rs PJRT bindings).
//!
//! The hero-blas stack touches XLA in exactly one module
//! (`runtime::registry`) plus the literal conversions; this stub mirrors
//! that API surface so the whole workspace builds and the unit-test
//! suite runs without the multi-GB xla_extension toolchain:
//!
//! - [`Literal`] is **fully functional** (typed host buffers with shape),
//!   so literal round-trip code and its tests behave like the real thing;
//! - [`PjRtClient::cpu`] succeeds (sessions construct), but
//!   `compile`/`execute` return honest `Error`s — device numerics need
//!   the real backend.
//!
//! Swap the `xla` dependency in the workspace `Cargo.toml` to the real
//! xla-rs to light up PJRT execution; no hero-blas source changes.

use std::fmt;

/// Stub error type (the real crate wraps XLA statuses).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const NO_BACKEND: &str =
    "xla stub: PJRT execution requires the real xla-rs backend (see rust/vendor/xla-stub)";

/// Element storage for [`Literal`] (the two dtypes hero-blas uses).
/// Public only because the [`NativeType`] conversion hooks name it.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Buf {
    F32(Vec<f32>),
    F64(Vec<f64>),
}

impl Buf {
    fn len(&self) -> usize {
        match self {
            Buf::F32(v) => v.len(),
            Buf::F64(v) => v.len(),
        }
    }
}

/// Marker + conversion trait for element types accepted by literals.
pub trait NativeType: Copy + Default + 'static {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Buf;
    #[doc(hidden)]
    fn unwrap(b: &Buf) -> Option<&[Self]>;
}

/// The real crate distinguishes array elements from native types; for
/// the stub they coincide.
pub trait ArrayElement: NativeType {}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Buf {
        Buf::F32(v)
    }
    fn unwrap(b: &Buf) -> Option<&[Self]> {
        match b {
            Buf::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for f64 {
    fn wrap(v: Vec<Self>) -> Buf {
        Buf::F64(v)
    }
    fn unwrap(b: &Buf) -> Option<&[Self]> {
        match b {
            Buf::F64(v) => Some(v),
            _ => None,
        }
    }
}

impl ArrayElement for f32 {}
impl ArrayElement for f64 {}

/// A typed host tensor (functional, unlike the execution surface).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    buf: Buf,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a flat slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], buf: T::wrap(data.to_vec()) }
    }

    /// Reshape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count < 0 || count as usize != self.buf.len() {
            return Err(Error(format!(
                "reshape: {:?} has {} elements, literal holds {}",
                dims,
                count,
                self.buf.len()
            )));
        }
        Ok(Literal { buf: self.buf.clone(), dims: dims.to_vec() })
    }

    /// Flatten back to a typed vec (dtype must match).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.buf)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("to_vec: literal dtype mismatch".into()))
    }

    pub fn element_count(&self) -> usize {
        self.buf.len()
    }

    /// Unwrap a 1-tuple result.  Stub executables never produce tuples,
    /// so this is the identity (kept for API compatibility).
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }
}

/// Parsed HLO module (the stub just carries the text).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read an HLO text file (real parsing happens in the backend).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation handle.
#[derive(Debug, Clone)]
pub struct XlaComputation(HloModuleProto);

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(proto.clone())
    }
}

/// The PJRT CPU client.  Construction succeeds so sessions can build;
/// compilation is where the stub stops.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(NO_BACKEND.into()))
    }
}

/// A compiled executable (unreachable through the stub client, but the
/// type must exist for signatures).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(NO_BACKEND.into()))
    }
}

/// A device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(NO_BACKEND.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f64() {
        let data: Vec<f64> = (0..12).map(|i| i as f64).collect();
        let lit = Literal::vec1(&data).reshape(&[3, 4]).unwrap();
        assert_eq!(lit.element_count(), 12);
        assert_eq!(lit.to_vec::<f64>().unwrap(), data);
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        let lit = Literal::vec1(&[1.0f32; 6]);
        assert!(lit.reshape(&[2, 3]).is_ok());
        assert!(lit.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn client_boots_but_refuses_to_compile() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: String::new() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err().to_string();
        assert!(err.contains("xla stub"), "{err}");
    }
}
