#!/usr/bin/env python3
"""CI smoke client for the flight recorder's serve ops.

Connects to a running hero-blas server, drives a few GEMM requests, then
validates that:

* ``trace_dump`` returns well-formed Chrome trace JSON with at least one
  duration (``ph: "X"``) event;
* ``metrics_prom`` returns a Prometheus text-exposition body with the
  pool counters and latency histogram series;
* both replies echo the request's ``req_id``.

The captured trace is written to ``trace_dump.json`` (the workflow
re-validates it with ``python3 -m json.tool``) and the server is shut
down on the way out.
"""

import json
import socket
import sys
import time


def main() -> int:
    port = int(sys.argv[1]) if len(sys.argv) > 1 else 7899
    sock = None
    for _ in range(240):
        try:
            sock = socket.create_connection(("127.0.0.1", port), timeout=5)
            break
        except OSError:
            time.sleep(0.5)
    if sock is None:
        print("serve never came up", file=sys.stderr)
        return 1
    f = sock.makefile("rw", encoding="utf-8", newline="\n")

    def rpc(req):
        f.write(json.dumps(req) + "\n")
        f.flush()
        return json.loads(f.readline())

    for seed in range(4):
        r = rpc({"op": "gemm", "n": 64, "mode": "device_only", "seed": seed})
        assert r.get("ok") is True, r

    dump = rpc({"op": "trace_dump", "req_id": "ci-trace"})
    assert dump.get("ok") is True, dump
    assert dump.get("req_id") == "ci-trace", dump
    events = dump.get("traceEvents")
    assert isinstance(events, list) and events, "flight recorder captured no events"
    phases = {e.get("ph") for e in events}
    assert "X" in phases, f"no duration events in {sorted(phases)}"
    with open("trace_dump.json", "w", encoding="utf-8") as out:
        json.dump(dump, out)

    prom = rpc({"op": "metrics_prom", "req_id": "ci-prom"})
    assert prom.get("ok") is True and prom.get("req_id") == "ci-prom", prom
    body = prom.get("body", "")
    assert "hero_jobs_submitted_total" in body, body[:200]
    assert "hero_request_latency_us_bucket" in body, body[:200]

    rpc({"op": "shutdown"})
    print(f"trace smoke ok: {len(events)} events, prom body {len(body)} bytes")
    return 0


if __name__ == "__main__":
    sys.exit(main())
