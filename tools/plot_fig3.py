#!/usr/bin/env python
"""Plot the Figure 3 reproduction from the harness CSV.

Usage:
    cargo run --release -- fig3 --out fig3.csv
    python tools/plot_fig3.py fig3.csv fig3.png
"""
import csv
import sys
from collections import defaultdict


def main() -> None:
    src = sys.argv[1] if len(sys.argv) > 1 else "fig3.csv"
    dst = sys.argv[2] if len(sys.argv) > 2 else "fig3.png"
    rows = list(csv.DictReader(open(src)))
    by_mode = defaultdict(list)
    for r in rows:
        by_mode[r["mode"]].append(r)

    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
        import numpy as np
    except ImportError:
        print("matplotlib unavailable; printing the table instead")
        for mode, rs in by_mode.items():
            for r in rs:
                print(mode, r["n"], float(r["total_s"]) * 1e3, "ms")
        return

    modes = list(by_mode)
    fig, ax = plt.subplots(figsize=(7, 4))
    width = 0.8 / len(modes)
    ns = sorted({int(r["n"]) for r in rows})
    x = np.arange(len(ns))
    regions = [("data_copy_s", "#d62728"), ("fork_join_s", "#ff7f0e"),
               ("compute_s", "#2ca02c"), ("host_compute_s", "#1f77b4")]
    for mi, mode in enumerate(modes):
        rs = {int(r["n"]): r for r in by_mode[mode]}
        bottom = np.zeros(len(ns))
        for key, color in regions:
            vals = np.array([float(rs[n][key]) * 1e3 if n in rs else 0.0 for n in ns])
            ax.bar(x + mi * width, vals, width, bottom=bottom, color=color,
                   label=key[:-2] if mi == 0 else None)
            bottom += vals
    ax.set_yscale("log")
    ax.set_xticks(x + 0.4 - width / 2)
    ax.set_xticklabels([str(n) for n in ns])
    ax.set_xlabel("matrix size n (f64 GEMM)")
    ax.set_ylabel("execution time [ms, log]")
    ax.set_title("Figure 3 reproduction: host vs offload, stacked regions\n"
                 f"(bar groups: {', '.join(modes)})")
    ax.legend()
    fig.tight_layout()
    fig.savefig(dst, dpi=150)
    print(f"wrote {dst}")


if __name__ == "__main__":
    main()
