//! Perf-trajectory gate: diff two `serve_throughput` snapshots.
//!
//! ```sh
//! cargo run --release --bin bench_compare -- BENCH_8.json bench_new.json
//! ```
//!
//! Both inputs are JSONL snapshots as written by the bench's `--out FILE`
//! flag (one JSON object per line; prose lines and `summary` lines are
//! ignored).  Points are matched across the two files by their knob
//! signature (pool/batching/cache/... plus client count), a per-sweep
//! delta table is printed, and the exit status is the gate:
//!
//! * `0`  — no matched point regressed beyond tolerance
//! * `1`  — at least one regression: throughput dropped more than 10 %
//!   or p99 latency grew more than 15 % vs the baseline
//! * `2`  — usage / parse error
//!
//! Points present in only one snapshot are reported but never fail the
//! gate (sweeps gain knobs across PRs); wall-clock noise on shared CI
//! runners is what the wide tolerances are for.

use std::process::ExitCode;

use hero_blas::util::json_lite::Json;

/// Throughput may drop at most this fraction vs the baseline.
const RPS_TOLERANCE: f64 = 0.10;
/// p99 latency may grow at most this fraction vs the baseline.
const P99_TOLERANCE: f64 = 0.15;

/// One comparable bench point: a knob signature plus the two gated
/// measurements (chain-workload points carry no p99).
#[derive(Debug, Clone, PartialEq)]
struct PointRec {
    sig: String,
    rps: f64,
    p99_us: Option<f64>,
}

/// The knobs that identify a sweep point across snapshots.
const SIG_KEYS: [&str; 12] = [
    "pool",
    "batching",
    "cache",
    "pipeline",
    "shared_b",
    "placement",
    "auto_mixed",
    "calibrate",
    "tracing",
    "kernel",
    "dag",
    "clients",
];

fn sig_value(v: &Json) -> Option<String> {
    match v {
        Json::Bool(b) => Some(b.to_string()),
        Json::Num(n) => Some(format!("{n}")),
        _ => None,
    }
}

/// Extract a comparable point from one snapshot line, or `None` for
/// lines the gate ignores (prose, summaries, malformed JSON).
fn point(line: &str) -> Option<PointRec> {
    let j = Json::parse(line.trim()).ok()?;
    j.get("bench")?;
    if j.get("summary").is_some() {
        return None;
    }
    if let Some(w) = j.get("workload").and_then(|v| v.as_str()) {
        // chain/dag sweeps: no rps field; derive throughput from the wall
        let chained = matches!(j.get("chained"), Some(Json::Bool(true)));
        let dag = matches!(j.get("dag"), Some(Json::Bool(true)));
        let requests = j.get("requests").and_then(|v| v.as_f64())?;
        let wall_ms = j.get("wall_ms").and_then(|v| v.as_f64())?;
        if wall_ms <= 0.0 {
            return None;
        }
        return Some(PointRec {
            sig: format!("{w} chained={chained} dag={dag}"),
            rps: requests * 1e3 / wall_ms,
            p99_us: None,
        });
    }
    let rps = j.get("rps").and_then(|v| v.as_f64())?;
    let mut sig = String::new();
    for k in SIG_KEYS {
        let v = match j.get(k) {
            Some(v) => sig_value(v)?,
            // the kernel and dag knobs postdate older baselines: a
            // snapshot written before they existed still matches the
            // registry's default-ON / DAG-off points
            None if k == "kernel" => "true".to_string(),
            None if k == "dag" => "false".to_string(),
            None => return None,
        };
        if !sig.is_empty() {
            sig.push(' ');
        }
        sig.push_str(&format!("{k}={v}"));
    }
    Some(PointRec { sig, rps, p99_us: j.get("p99_us").and_then(|v| v.as_f64()) })
}

fn parse_snapshot(text: &str) -> Vec<PointRec> {
    text.lines().filter_map(point).collect()
}

/// One row of the delta table.
#[derive(Debug, Clone)]
struct Delta {
    sig: String,
    rps_old: f64,
    rps_new: f64,
    p99_old: Option<f64>,
    p99_new: Option<f64>,
    regressed: bool,
    reason: &'static str,
}

fn pct(old: f64, new: f64) -> f64 {
    if old <= 0.0 {
        0.0
    } else {
        (new - old) / old * 100.0
    }
}

/// Match points by signature and apply the gate thresholds.
fn compare(old: &[PointRec], new: &[PointRec]) -> Vec<Delta> {
    let mut rows = Vec::new();
    for o in old {
        let Some(n) = new.iter().find(|n| n.sig == o.sig) else {
            continue;
        };
        let rps_bad = n.rps < o.rps * (1.0 - RPS_TOLERANCE);
        let p99_bad = match (o.p99_us, n.p99_us) {
            (Some(op), Some(np)) if op > 0.0 => np > op * (1.0 + P99_TOLERANCE),
            _ => false,
        };
        let reason = match (rps_bad, p99_bad) {
            (true, true) => "rps+p99 regression",
            (true, false) => "rps regression",
            (false, true) => "p99 regression",
            (false, false) => "ok",
        };
        rows.push(Delta {
            sig: o.sig.clone(),
            rps_old: o.rps,
            rps_new: n.rps,
            p99_old: o.p99_us,
            p99_new: n.p99_us,
            regressed: rps_bad || p99_bad,
            reason,
        });
    }
    rows
}

fn fmt_p99(v: Option<f64>) -> String {
    match v {
        Some(p) => format!("{p:.0}"),
        None => "-".into(),
    }
}

fn print_table(rows: &[Delta], old_n: usize, new_n: usize) {
    println!(
        "{:<90} {:>9} {:>9} {:>7}  {:>8} {:>8} {:>7}  {}",
        "point",
        "rps_old",
        "rps_new",
        "drps%",
        "p99_old",
        "p99_new",
        "dp99%",
        "status"
    );
    for r in rows {
        let dp99 = match (r.p99_old, r.p99_new) {
            (Some(o), Some(n)) if o > 0.0 => format!("{:+.1}", pct(o, n)),
            _ => "-".into(),
        };
        println!(
            "{:<90} {:>9.1} {:>9.1} {:>+7.1}  {:>8} {:>8} {:>7}  {}",
            r.sig,
            r.rps_old,
            r.rps_new,
            pct(r.rps_old, r.rps_new),
            fmt_p99(r.p99_old),
            fmt_p99(r.p99_new),
            dp99,
            r.reason,
        );
    }
    let matched = rows.len();
    let regressed = rows.iter().filter(|r| r.regressed).count();
    println!(
        "\nmatched {matched} points (baseline {old_n}, new {new_n}); \
         {regressed} regression(s); gate: rps -{:.0}% / p99 +{:.0}%",
        RPS_TOLERANCE * 100.0,
        P99_TOLERANCE * 100.0
    );
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().collect();
    if args.len() != 3 {
        eprintln!("usage: bench_compare <baseline.jsonl> <new.jsonl>");
        return ExitCode::from(2);
    }
    let read = |p: &str| match std::fs::read_to_string(p) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("bench_compare: cannot read {p}: {e}");
            None
        }
    };
    let (Some(old_text), Some(new_text)) = (read(&args[1]), read(&args[2])) else {
        return ExitCode::from(2);
    };
    let old = parse_snapshot(&old_text);
    let new = parse_snapshot(&new_text);
    if old.is_empty() {
        eprintln!("bench_compare: no bench points in baseline {}", args[1]);
        return ExitCode::from(2);
    }
    let rows = compare(&old, &new);
    print_table(&rows, old.len(), new.len());
    if rows.iter().any(|r| r.regressed) {
        ExitCode::from(1)
    } else {
        ExitCode::SUCCESS
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BASE: &str = r#"
== serve throughput: prose header, ignored ==
{"bench": "serve_throughput", "n": 64, "pool": 1, "batching": false, "cache": false, "pipeline": false, "shared_b": false, "placement": false, "auto_mixed": false, "calibrate": false, "tracing": true, "clients": 1, "requests": 12, "wall_ms": 30.0, "rps": 400.0, "p50_us": 512, "p99_us": 2048, "p999_us": 4096, "speedup_vs_serial": 1.00}
{"bench": "serve_throughput", "n": 64, "pool": 4, "batching": true, "cache": false, "pipeline": false, "shared_b": false, "placement": false, "auto_mixed": false, "calibrate": false, "tracing": true, "clients": 4, "requests": 24, "wall_ms": 20.0, "rps": 1200.0, "p50_us": 256, "p99_us": 1024, "p999_us": 2048, "speedup_vs_serial": 3.00}
{"bench": "serve_throughput", "summary": "copy_bytes_cut", "value": 3.10}
{"bench": "serve_throughput", "workload": "chain_mlp", "chained": true, "requests": 24, "wall_ms": 12.0, "bytes_to_device": 100, "chain_bytes_elided": 50, "chains": 24}
"#;

    fn degrade(rps_factor: f64, p99_factor: f64) -> String {
        let mut out = String::new();
        for p in parse_snapshot(BASE) {
            // re-render a minimal comparable line from the parsed point
            if p.sig.starts_with("chain_mlp") {
                let wall = 24.0 * 1e3 / (p.rps * rps_factor);
                out.push_str(&format!(
                    "{{\"bench\": \"b\", \"workload\": \"chain_mlp\", \
                     \"chained\": {}, \"requests\": 24, \"wall_ms\": {wall}}}\n",
                    p.sig.contains("chained=true"),
                ));
            } else {
                let kv = p
                    .sig
                    .split(' ')
                    .map(|s| {
                        let (k, v) = s.split_once('=').unwrap();
                        format!("\"{k}\": {v}")
                    })
                    .collect::<Vec<_>>()
                    .join(", ");
                out.push_str(&format!(
                    "{{\"bench\": \"b\", {kv}, \"rps\": {}, \"p99_us\": {}}}\n",
                    p.rps * rps_factor,
                    p.p99_us.unwrap() * p99_factor,
                ));
            }
        }
        out
    }

    #[test]
    fn parses_points_and_skips_prose_and_summaries() {
        let pts = parse_snapshot(BASE);
        assert_eq!(pts.len(), 3);
        assert!(pts[0].sig.contains("pool=1"));
        assert!(pts[0].sig.contains("clients=1"));
        assert_eq!(pts[0].p99_us, Some(2048.0));
        assert_eq!(pts[2].sig, "chain_mlp chained=true dag=false");
        assert!((pts[2].rps - 2000.0).abs() < 1e-9);
        assert_eq!(pts[2].p99_us, None);
    }

    #[test]
    fn missing_kernel_knob_defaults_to_true() {
        // pre-registry baselines carry no "kernel" field; they must
        // keep matching snapshots written with the default-ON registry
        let pts = parse_snapshot(BASE);
        assert!(pts[0].sig.contains("kernel=true"));
        let with_knob = BASE.replace("\"tracing\": true", "\"tracing\": true, \"kernel\": true");
        let new = parse_snapshot(&with_knob);
        let rows = compare(&pts, &new);
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|r| !r.regressed));
        // an explicit OFF point is a different signature: never matched
        let off = BASE.replace("\"tracing\": true", "\"tracing\": true, \"kernel\": false");
        assert!(compare(&pts, &parse_snapshot(&off)).len() == 1, "chain point only");
    }

    #[test]
    fn missing_dag_knob_defaults_to_false() {
        // pre-DAG baselines carry no "dag" field; they must keep
        // matching snapshots written with the DAG-off default points
        let pts = parse_snapshot(BASE);
        assert!(pts[0].sig.contains("dag=false"));
        let with_knob = BASE.replace("\"tracing\": true", "\"tracing\": true, \"dag\": false");
        let new = parse_snapshot(&with_knob);
        let rows = compare(&pts, &new);
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|r| !r.regressed));
        // an explicit DAG-workload point is a different signature
        let on = BASE.replace("\"tracing\": true", "\"tracing\": true, \"dag\": true");
        assert!(compare(&pts, &parse_snapshot(&on)).len() == 1, "chain point only");
    }

    #[test]
    fn self_compare_has_no_regressions() {
        let pts = parse_snapshot(BASE);
        let rows = compare(&pts, &pts);
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|r| !r.regressed));
    }

    #[test]
    fn small_drift_within_tolerance_passes() {
        let old = parse_snapshot(BASE);
        let new = parse_snapshot(&degrade(0.95, 1.10));
        let rows = compare(&old, &new);
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|r| !r.regressed));
    }

    #[test]
    fn throughput_regression_trips_the_gate() {
        let old = parse_snapshot(BASE);
        let new = parse_snapshot(&degrade(0.80, 1.0));
        let rows = compare(&old, &new);
        assert!(rows.iter().all(|r| r.regressed));
        assert!(rows.iter().any(|r| r.reason == "rps regression"));
    }

    #[test]
    fn p99_regression_trips_the_gate() {
        let old = parse_snapshot(BASE);
        let new = parse_snapshot(&degrade(1.0, 1.30));
        let rows = compare(&old, &new);
        let bad: Vec<_> = rows.iter().filter(|r| r.regressed).collect();
        // both percentile-carrying points regress; the chain point has no p99
        assert_eq!(bad.len(), 2);
        assert!(bad.iter().all(|r| r.reason == "p99 regression"));
    }

    #[test]
    fn unmatched_points_are_skipped_not_failed() {
        let old = parse_snapshot(BASE);
        let rows = compare(&old, &old[..1].to_vec());
        assert_eq!(rows.len(), 1);
        assert!(!rows[0].regressed);
    }
}
